"""CI gates for the telemetry plane.

Two checks, both runnable as modules (wired into ``scripts/ci.sh``):

* ``python -m repro.obs.check schema`` — runs a small but *complete*
  workload (sharded multi-scenario serving + hot deploy + gauges) and
  asserts the snapshot against the golden metric catalog: every expected
  metric present with its declared type / unit / label names, units
  present on everything, no metric exceeding its cardinality bound, and
  the Prometheus rendering well-formed.  The catalog in
  ``EXPECTED_METRICS`` is the same one documented in
  ``docs/OBSERVABILITY.md`` — a metric added or renamed without updating
  both fails here, which is the point: the snapshot schema is an
  interface other tooling parses.
* ``python -m repro.obs.check overhead`` — measures instrumented vs
  disabled-telemetry ``FeatureService.request`` at smoke size and asserts
  the instrumented path stays within a small multiplicative bound (plus
  an additive floor, so micro-second jitter on a fast machine cannot
  flake the gate).
"""

from __future__ import annotations

import sys
from typing import Dict, Tuple

# name -> (type, unit, label names).  THE golden catalog; keep in sync
# with docs/OBSERVABILITY.md.
EXPECTED_METRICS: Dict[str, Tuple[str, str, Tuple[str, ...]]] = {
    "service_requests_total": ("counter", "1", ("service", "scenario")),
    "request_latency_seconds": ("histogram", "s", ("service",)),
    "queue_wait_seconds": ("histogram", "s", ("service",)),
    "batch_occupancy_ratio": ("gauge", "1", ("service",)),
    "padding_rows_total": ("counter", "1", ("layer",)),
    "padding_waste_ratio": ("gauge", "1", ("layer",)),
    "span_seconds": ("histogram", "s", ("name", "kind")),
    "shard_dispatch_rows_total": ("counter", "1", ("scenario", "shard")),
    "route_rows_total": ("counter", "1", ("path",)),
    "query_compile_seconds": ("histogram", "s", ("program", "mode")),
    "preagg_hits_total": ("counter", "1", ("agg",)),
    "preagg_fallback_total": ("counter", "1", ("agg",)),
    "kernel_dispatch_total": ("counter", "1", ("kernel", "impl")),
    "ingest_freshness_seconds": ("histogram", "s", ("table",)),
    "ingest_rows_total": ("counter", "1", ("table",)),
    "ring_occupancy_ratio": ("gauge", "1", ("table", "placement")),
    "ring_evicted_rows_total": ("gauge", "1", ("table", "placement")),
    "hot_deploys_total": ("counter", "1", ("service",)),
    "backfill_rows_total": ("counter", "1", ("table",)),
    "export_rows_total": ("counter", "1", ("view",)),
    "export_freshness_seconds": ("histogram", "s", ("view",)),
}

# populated only when a layout sets a TTL — optional in the golden set
OPTIONAL_METRICS: Dict[str, Tuple[str, str, Tuple[str, ...]]] = {
    "ring_ttl_expired_rows": ("gauge", "1", ("table",)),
}

EXPECTED_SPAN_NAMES = {
    "request", "query.route", "query.compute", "query.scatter",
    "route.device", "ingest",
    "hot_deploy", "hot_deploy.plan", "hot_deploy.compile",
    "migrate", "migrate.diff", "migrate.carry", "migrate.place",
    "backfill", "backfill.ring", "backfill.bucket", "export",
}


def _workload(tel):
    """Small sharded multi-scenario workload + hot deploy: touches every
    instrumented layer so the snapshot carries the full catalog."""
    import numpy as np

    from repro.core import (
        Col, FeatureView, range_window, rows_window, w_count, w_mean, w_sum,
    )
    from repro.data.synthetic import FRAUD_SCHEMA
    from repro.obs import use_telemetry
    from repro.serve.router import ShardRouter
    from repro.serve.service import BatchScheduler, FeatureService

    amt = Col("amount")
    w1 = range_window(600, bucket=64)
    v1 = FeatureView("fraud", FRAUD_SCHEMA, {"s": w_sum(amt, w1)})
    v2 = FeatureView(
        "risk", FRAUD_SCHEMA,
        {"m": w_mean(amt, w1), "c5": w_count(amt, rows_window(5))},
    )
    v3 = FeatureView("velocity", FRAUD_SCHEMA, {"c8": w_count(amt, rows_window(8))})

    with use_telemetry(tel):
        svc = FeatureService.build_multi(
            "plane", [v1, v2], num_keys=32, sharded=True, num_shards=4,
            capacity=64,
        )
        router = ShardRouter(
            svc, BatchScheduler(max_batch=16, max_wait_us=2_000)
        )
        rng = np.random.default_rng(0)
        now = 0
        for i in range(40):
            router.submit(
                dict(
                    card=int(rng.integers(0, 32)),
                    ts=100_000 + i,
                    amount=float(rng.gamma(1.5, 60.0)),
                    mcc=int(rng.integers(0, 32)),
                    device=int(rng.integers(0, 8)),
                    geo=int(rng.integers(0, 16)),
                ),
                now_us=now,
                scenario="fraud" if i % 2 else "risk",
            )
            now += 250
            router.pump(now_us=now)
        router.drain(now_us=now)
        svc.hot_deploy(v3)
        for i in range(4):
            router.submit(
                dict(
                    card=i, ts=101_000 + i, amount=10.0, mcc=0, device=0,
                    geo=0,
                ),
                now_us=now, scenario="velocity",
            )
            now += 250
        # a couple of requests through the retained host-routed oracle
        # flavour, so route_rows_total{path=host} and the host path's
        # query.compute span stay exercised alongside route.device
        svc.store.device_routing = False
        for i in range(4):
            router.submit(
                dict(
                    card=i, ts=102_000 + i, amount=5.0, mcc=0, device=0,
                    geo=0,
                ),
                now_us=now, scenario="fraud",
            )
            now += 250
        router.drain(now_us=now)
        svc.store.device_routing = True
        svc.store.record_gauges()

        # offline bridge: a hot deploy needing aged-out history (40
        # rows/key vs 8-row rings) spliced from offline storage, plus a
        # training-set export — the backfill + export metric families
        from repro.core import ScenarioPlane, Signature
        from repro.data.synthetic import MULTITABLE_DB, multitable_stream
        from repro.offline import BackfillSource, export_training_set
        from repro.scenarios import multi_scenario_views, multi_table_view

        tabs = multitable_stream(
            np.random.default_rng(5), 160, num_accounts=4,
            num_merchants=4, t_max=20_000,
        )
        mviews = multi_scenario_views()[:2]
        sig = FeatureView(
            name="merchant_mix",
            features={
                "sig_cnt": w_count(
                    Signature((Col("merchant"),), bits=8),
                    range_window(3600, bucket=64),
                ),
            },
            database=MULTITABLE_DB,
        )
        plane = ScenarioPlane(
            mviews, num_keys=4, capacity=8, num_buckets=512,
            bucket_size=64, secondary_num_keys={"merchants": 4},
        )
        for t in plane.store._sec_names:
            kc = MULTITABLE_DB.table(t).key
            cols = tabs[t]
            o = np.lexsort((cols["ts"], cols[kc]))
            plane.ingest_table(t, {c: v[o] for c, v in cols.items()})
        tx = tabs["transactions"]
        o = np.lexsort((tx["ts"], tx["account"]))
        plane.ingest({c: v[o] for c, v in tx.items()})
        report = plane.evolve(
            mviews + [sig],
            backfill=BackfillSource(MULTITABLE_DB, tabs),
            capacity=32,
        )
        assert report.exact and report.backfilled, report.describe()
        export_training_set(
            multi_table_view(), tx, n=8,
            secondary={t: c for t, c in tabs.items() if t != "transactions"},
        )
    return tel


def schema_check(verbose: bool = True) -> None:
    """Golden-catalog assertion over a full-workload snapshot."""
    from repro.obs import Telemetry

    tel = Telemetry()
    _workload(tel)
    snap = tel.snapshot()

    assert snap["schema_version"] == Telemetry.SCHEMA_VERSION, snap.keys()
    metrics = snap["metrics"]
    problems = []
    for name, (typ, unit, labels) in EXPECTED_METRICS.items():
        if name not in metrics:
            problems.append(f"missing metric {name!r}")
            continue
        m = metrics[name]
        if m["type"] != typ:
            problems.append(f"{name}: type {m['type']!r} != {typ!r}")
        if m["unit"] != unit:
            problems.append(f"{name}: unit {m['unit']!r} != {unit!r}")
        if tuple(m["labels"]) != labels:
            problems.append(
                f"{name}: labels {tuple(m['labels'])} != {labels}"
            )
        if not m["series"]:
            problems.append(f"{name}: no series recorded by the workload")
    golden = set(EXPECTED_METRICS) | set(OPTIONAL_METRICS)
    for name, m in metrics.items():
        if name not in golden:
            problems.append(
                f"unexpected metric {name!r} — add it to "
                "EXPECTED_METRICS and docs/OBSERVABILITY.md"
            )
        if not m.get("unit"):
            problems.append(f"{name}: empty unit")

    # cardinality: bounded per metric (registry raises on exceed; assert
    # the workload stays well inside the caps)
    for name, metric in tel.metrics.metrics().items():
        n = metric.series_count()
        if n > metric.max_series:
            problems.append(
                f"{name}: {n} series > cap {metric.max_series}"
            )

    # span taxonomy: every expected stage traced at least once
    seen_spans = {
        s["labels"]["name"]
        for s in metrics.get("span_seconds", {}).get("series", ())
    }
    missing_spans = EXPECTED_SPAN_NAMES - seen_spans
    if missing_spans:
        problems.append(f"span names never traced: {sorted(missing_spans)}")

    # Prometheus rendering: every metric family present, parseable shape
    prom = tel.to_prometheus()
    for name in EXPECTED_METRICS:
        if f"# TYPE {name} " not in prom:
            problems.append(f"{name}: missing from Prometheus exposition")

    # snapshot is JSON-stable
    import json

    json.loads(json.dumps(snap))

    if problems:
        raise AssertionError(
            "telemetry schema check failed:\n  " + "\n  ".join(problems)
        )
    if verbose:
        print(
            f"telemetry schema check OK: {len(metrics)} metrics, "
            f"{len(seen_spans)} span names, Prometheus + JSON render"
        )


def overhead_check(
    bound_ratio: float = 2.5,
    floor_s: float = 2e-3,
    iters: int = 40,
    verbose: bool = True,
) -> None:
    """Instrumented ``FeatureService.request`` must stay within
    ``bound_ratio``× the disabled-telemetry path (+``floor_s`` additive
    slack) at smoke size, comparing medians over ``iters`` calls."""
    import statistics
    import time

    import numpy as np

    from repro.core import Col, FeatureView, range_window, rows_window, w_count, w_sum
    from repro.data.synthetic import FRAUD_SCHEMA
    from repro.obs import Telemetry, use_telemetry
    from repro.serve.service import FeatureService

    amt = Col("amount")
    view = FeatureView(
        "ovh", FRAUD_SCHEMA,
        {
            "s": w_sum(amt, range_window(600, bucket=64)),
            "c5": w_count(amt, rows_window(5)),
        },
    )
    rng = np.random.default_rng(0)

    def batch(i, n=16):
        return {
            "card": rng.integers(0, 32, n),
            "ts": np.arange(200_000 + i * n, 200_000 + (i + 1) * n),
            "amount": rng.gamma(1.5, 60.0, n).astype(np.float32),
            "mcc": rng.integers(0, 32, n),
            "device": rng.integers(0, 8, n),
            "geo": rng.integers(0, 16, n),
        }

    def run(enabled: bool) -> float:
        tel = Telemetry(enabled=enabled)
        with use_telemetry(tel):
            svc = FeatureService.build(
                "ovh", view, num_keys=32, sharded=True, num_shards=4,
                capacity=64,
            )
            svc.request(batch(0))  # warm the compile caches
            times = []
            for i in range(1, iters + 1):
                t0 = time.perf_counter()
                svc.request(batch(i))
                times.append(time.perf_counter() - t0)
        return statistics.median(times)

    base = run(enabled=False)
    inst = run(enabled=True)
    limit = base * bound_ratio + floor_s
    if inst > limit:
        raise AssertionError(
            f"telemetry overhead too high: instrumented median "
            f"{inst * 1e3:.3f} ms > {bound_ratio}x disabled median "
            f"{base * 1e3:.3f} ms + {floor_s * 1e3:.1f} ms floor"
        )
    if verbose:
        print(
            f"telemetry overhead OK: instrumented {inst * 1e3:.3f} ms vs "
            f"disabled {base * 1e3:.3f} ms (limit {limit * 1e3:.3f} ms)"
        )


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    which = args[0] if args else "all"
    if which in ("schema", "all"):
        schema_check()
    if which in ("overhead", "all"):
        overhead_check()
    if which not in ("schema", "overhead", "all"):
        print(f"unknown check {which!r}; use schema | overhead | all")
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
