"""Plane-wide observability: one clock, one metric registry, one tracer.

Public surface::

    from repro.obs import (
        Clock, FakeClock,               # the plane's single time source
        Telemetry, get_telemetry,       # process-wide bundle
        set_telemetry, reset_telemetry, use_telemetry,
        MetricRegistry, Counter, Gauge, Histogram,
        Tracer, Span,
    )

``repro.obs.report`` renders a telemetry snapshot as a markdown
dashboard (``python -m repro.obs.report``); ``repro.obs.check`` holds the
CI gates (snapshot-schema golden set + instrumentation overhead bound).
"""

from repro.obs.telemetry import (
    DEFAULT_BUCKETS_S,
    Clock,
    Counter,
    FakeClock,
    Gauge,
    Histogram,
    MetricCardinalityError,
    MetricRegistry,
    Telemetry,
    get_telemetry,
    reset_telemetry,
    set_telemetry,
    use_telemetry,
)
from repro.obs.tracing import Span, Tracer

__all__ = [
    "DEFAULT_BUCKETS_S",
    "Clock",
    "Counter",
    "FakeClock",
    "Gauge",
    "Histogram",
    "MetricCardinalityError",
    "MetricRegistry",
    "Telemetry",
    "get_telemetry",
    "reset_telemetry",
    "set_telemetry",
    "use_telemetry",
    "Span",
    "Tracer",
]
