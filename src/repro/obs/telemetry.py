"""Plane-wide metric registry + the one clock every layer shares.

FeatInsight's headline claims are *observability* claims — feature
computation "taking up to 70% of the overall latency", "millisecond-level"
feature updates — and a serving plane that cannot measure its own
queue-wait / routing / device-compute / freshness split cannot honestly
report either number.  This module is the measurement substrate:

* :class:`Clock` — ONE injectable time source.  ``now()`` /` `now_us()``
  are monotonic (latency spans, scheduler deadlines), ``time()`` is wall
  epoch seconds (registry deploy stamps).  ``BatchScheduler``,
  ``FeatureRegistry``, the router, and every tracer span resolve their
  notion of time through the installed telemetry's clock, so one
  :class:`FakeClock` drives the entire plane deterministically under test.
* :class:`MetricRegistry` — labeled counters / gauges / histograms with a
  **stable snapshot schema** (``snapshot() -> dict``, JSON-safe), a
  Prometheus text-exposition exporter, and a hard per-metric series cap so
  label cardinality cannot grow without bound (the classic metrics-plane
  failure mode).  Histograms keep fixed log-spaced buckets plus a bounded
  reservoir of recent raw values for tail percentiles.
* :class:`Telemetry` — the bundle (clock + metrics + tracer) with a
  process-wide default: ``get_telemetry()`` / ``set_telemetry()`` /
  ``use_telemetry()``.  ``Telemetry(enabled=False)`` is the null plane:
  every record call short-circuits, which is what the CI overhead gate
  compares the instrumented request path against.

The metric *catalog* (every name, its labels and unit) is documented in
``docs/OBSERVABILITY.md`` and schema-gated in CI via
:func:`repro.obs.check.schema_check`.
"""

from __future__ import annotations

import dataclasses
import json
import math
import threading
import time as _time
from collections import deque
from typing import Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Clock",
    "FakeClock",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "MetricCardinalityError",
    "Telemetry",
    "get_telemetry",
    "set_telemetry",
    "reset_telemetry",
    "use_telemetry",
    "DEFAULT_BUCKETS_S",
]


# ---------------------------------------------------------------------------
# The one clock
# ---------------------------------------------------------------------------


class Clock:
    """The plane's single time source (monotonic + wall).

    ``now()`` (float s) and ``now_us()`` (int µs) are monotonic — spans,
    queue-wait deadlines, latency attribution.  ``time()`` is wall epoch
    seconds — deploy-record stamps.  Subclass / replace with
    :class:`FakeClock` to drive every consumer from one deterministic
    counter.
    """

    def now(self) -> float:
        return _time.perf_counter()

    def now_us(self) -> int:
        return _time.monotonic_ns() // 1_000

    def time(self) -> float:
        return _time.time()


class FakeClock(Clock):
    """Deterministic clock for tests: one counter feeds monotonic AND wall
    time, advanced explicitly (``advance`` seconds / ``tick`` µs)."""

    def __init__(self, start_s: float = 0.0, epoch_s: float = 1_000_000.0):
        self._t = float(start_s)
        self._epoch = float(epoch_s)

    def advance(self, seconds: float) -> "FakeClock":
        if seconds < 0:
            raise ValueError(f"FakeClock cannot rewind ({seconds})")
        self._t += float(seconds)
        return self

    def tick(self, us: int = 1) -> "FakeClock":
        return self.advance(us / 1e6)

    def now(self) -> float:
        return self._t

    def now_us(self) -> int:
        return int(round(self._t * 1e6))

    def time(self) -> float:
        return self._epoch + self._t


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


class MetricCardinalityError(RuntimeError):
    """A metric exceeded its label-series cap — unbounded cardinality is a
    bug in the instrumentation, not a load condition, so fail loudly."""


# log-spaced latency buckets: 10 µs .. 30 s (covers queue waits, device
# compute, compile times, and migration phases in one scheme)
DEFAULT_BUCKETS_S: Tuple[float, ...] = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
    1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0,
)

_RESERVOIR = 512  # recent raw values kept per histogram series (tails)


def _label_values(
    declared: Tuple[str, ...], labels: Dict[str, str], name: str
) -> Tuple[str, ...]:
    if set(labels) != set(declared):
        raise ValueError(
            f"metric {name!r} declared labels {declared}, got "
            f"{tuple(sorted(labels))} — label keys are part of the schema"
        )
    return tuple(str(labels[k]) for k in declared)


@dataclasses.dataclass
class _MetricBase:
    name: str
    help: str
    unit: str
    label_names: Tuple[str, ...]
    max_series: int
    enabled: bool = True

    def __post_init__(self):
        self._series: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()

    def _slot(self, labels: Dict[str, str], make):
        key = _label_values(self.label_names, labels, self.name)
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.get(key)
                if s is None:
                    if len(self._series) >= self.max_series:
                        raise MetricCardinalityError(
                            f"metric {self.name!r} exceeded max_series="
                            f"{self.max_series} (new series {key!r}); "
                            "bound the label domain or raise the cap "
                            "explicitly"
                        )
                    s = make()
                    self._series[key] = s
        return s

    def series_count(self) -> int:
        return len(self._series)

    def _snap_series(self) -> List[Dict]:
        out = []
        for key in sorted(self._series):
            out.append(
                {
                    "labels": dict(zip(self.label_names, key)),
                    **self._snap_one(self._series[key]),
                }
            )
        return out

    def snapshot(self) -> Dict:
        return {
            "type": self.kind,
            "unit": self.unit,
            "help": self.help,
            "labels": list(self.label_names),
            "series": self._snap_series(),
        }


class Counter(_MetricBase):
    kind = "counter"

    def inc(self, n: float = 1.0, **labels: str) -> None:
        if not self.enabled:
            return
        slot = self._slot(labels, lambda: [0.0])
        slot[0] += n

    def inc_along(
        self,
        label: str,
        values: Sequence[str],
        counts: Sequence[float],
        **labels: str,
    ) -> None:
        """Vectorized ``inc``: fold an aligned batch of
        (``label=values[i]``, ``counts[i]``) increments into the series
        that differ only in ``label`` (the remaining labels are fixed) in
        ONE call.  Zero counts are skipped, so hot paths can hand a dense
        histogram (e.g. rows per shard) without a per-series ``inc`` loop
        or series churn for empty buckets."""
        if not self.enabled:
            return
        for v, n in zip(values, counts):
            if n:
                slot = self._slot({**labels, label: str(v)}, lambda: [0.0])
                slot[0] += float(n)

    def value(self, **labels: str) -> float:
        key = _label_values(self.label_names, labels, self.name)
        s = self._series.get(key)
        return float(s[0]) if s is not None else 0.0

    def total(self) -> float:
        return float(sum(s[0] for s in self._series.values()))

    def _snap_one(self, s) -> Dict:
        return {"value": float(s[0])}


class Gauge(_MetricBase):
    kind = "gauge"

    def set(self, v: float, **labels: str) -> None:
        if not self.enabled:
            return
        slot = self._slot(labels, lambda: [0.0])
        slot[0] = float(v)

    def value(self, **labels: str) -> float:
        key = _label_values(self.label_names, labels, self.name)
        s = self._series.get(key)
        return float(s[0]) if s is not None else 0.0

    def _snap_one(self, s) -> Dict:
        return {"value": float(s[0])}


class _HistSeries:
    __slots__ = ("count", "sum", "max", "buckets", "recent")

    def __init__(self, n_bounds: int):
        self.count = 0.0
        self.sum = 0.0
        self.max = 0.0
        self.buckets = [0.0] * (n_bounds + 1)  # +inf overflow bucket
        self.recent: Deque[float] = deque(maxlen=_RESERVOIR)


class Histogram(_MetricBase):
    kind = "histogram"

    def __init__(self, *args, bounds: Sequence[float] = DEFAULT_BUCKETS_S,
                 **kw):
        super().__init__(*args, **kw)
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds}")

    def observe(self, v: float, n: float = 1.0, **labels: str) -> None:
        """Record ``n`` observations of value ``v`` (``n > 1`` weights a
        whole batch of identical per-row observations, e.g. one ingest
        batch's freshness counted once per row)."""
        if not self.enabled:
            return
        s: _HistSeries = self._slot(
            labels, lambda: _HistSeries(len(self.bounds))
        )
        v = float(v)
        s.count += n
        s.sum += v * n
        if v > s.max:
            s.max = v
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        s.buckets[i] += n
        s.recent.append(v)

    def observe_array(self, values: Iterable[float], **labels: str) -> None:
        for v in values:
            self.observe(float(v), **labels)

    # -- reads ---------------------------------------------------------------

    def _get(self, labels: Dict[str, str]) -> Optional[_HistSeries]:
        key = _label_values(self.label_names, labels, self.name)
        return self._series.get(key)

    def count(self, **labels: str) -> float:
        s = self._get(labels)
        return float(s.count) if s is not None else 0.0

    def sum(self, **labels: str) -> float:
        s = self._get(labels)
        return float(s.sum) if s is not None else 0.0

    def mean(self, **labels: str) -> float:
        s = self._get(labels)
        if s is None or s.count == 0:
            return 0.0
        return s.sum / s.count

    def percentile(self, p: float, **labels: str) -> float:
        """Tail estimate over the bounded reservoir of recent raw values."""
        s = self._get(labels)
        if s is None or not s.recent:
            return 0.0
        vals = sorted(s.recent)
        rank = (p / 100.0) * (len(vals) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(vals) - 1)
        frac = rank - lo
        return vals[lo] * (1 - frac) + vals[hi] * frac

    def _snap_one(self, s: _HistSeries) -> Dict:
        return {
            "count": float(s.count),
            "sum": float(s.sum),
            "max": float(s.max),
            "buckets": [
                [b, float(c)]
                for b, c in zip(list(self.bounds) + ["+Inf"], s.buckets)
            ],
            "p50": self._reservoir_pct(s, 50.0),
            "p95": self._reservoir_pct(s, 95.0),
            "p99": self._reservoir_pct(s, 99.0),
        }

    @staticmethod
    def _reservoir_pct(s: _HistSeries, p: float) -> float:
        if not s.recent:
            return 0.0
        vals = sorted(s.recent)
        rank = (p / 100.0) * (len(vals) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(vals) - 1)
        frac = rank - lo
        return vals[lo] * (1 - frac) + vals[hi] * frac


class MetricRegistry:
    """Get-or-create registry of labeled metrics.

    Re-registration with a different type / unit / label set raises — the
    snapshot schema is a contract, not a convention.  ``max_series``
    bounds per-metric label cardinality (override per metric for known
    wider-but-bounded domains like (scenario, shard)).
    """

    def __init__(self, enabled: bool = True, max_series: int = 256):
        self.enabled = enabled
        self.max_series = max_series
        self._metrics: Dict[str, _MetricBase] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, help, unit, labels, max_series, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(
                        name, help, unit, tuple(labels),
                        max_series or self.max_series,
                        enabled=self.enabled, **kw,
                    )
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}"
            )
        if m.label_names != tuple(labels) or m.unit != unit:
            raise ValueError(
                f"metric {name!r} re-registered with different schema: "
                f"had (unit={m.unit!r}, labels={m.label_names}), got "
                f"(unit={unit!r}, labels={tuple(labels)})"
            )
        return m

    def counter(
        self, name: str, help: str = "", unit: str = "1",
        labels: Sequence[str] = (), max_series: Optional[int] = None,
    ) -> Counter:
        return self._get(Counter, name, help, unit, labels, max_series)

    def gauge(
        self, name: str, help: str = "", unit: str = "1",
        labels: Sequence[str] = (), max_series: Optional[int] = None,
    ) -> Gauge:
        return self._get(Gauge, name, help, unit, labels, max_series)

    def histogram(
        self, name: str, help: str = "", unit: str = "s",
        labels: Sequence[str] = (), max_series: Optional[int] = None,
        bounds: Sequence[float] = DEFAULT_BUCKETS_S,
    ) -> Histogram:
        return self._get(
            Histogram, name, help, unit, labels, max_series, bounds=bounds
        )

    def metrics(self) -> Dict[str, _MetricBase]:
        return dict(self._metrics)

    def snapshot(self) -> Dict[str, Dict]:
        return {
            name: self._metrics[name].snapshot()
            for name in sorted(self._metrics)
        }

    # -- Prometheus text exposition -----------------------------------------

    @staticmethod
    def _esc(v: str) -> str:
        return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")

    @classmethod
    def _fmt_labels(cls, labels: Dict[str, str], extra: str = "") -> str:
        parts = [f'{k}="{cls._esc(str(v))}"' for k, v in labels.items()]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def to_prometheus(self) -> str:
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            lines.append(f"# HELP {name} {m.help} (unit: {m.unit})")
            lines.append(f"# TYPE {name} {m.kind}")
            snap = m.snapshot()
            for s in snap["series"]:
                lab = s["labels"]
                if m.kind in ("counter", "gauge"):
                    lines.append(
                        f"{name}{self._fmt_labels(lab)} {s['value']:.10g}"
                    )
                else:
                    acc = 0.0
                    for le, c in s["buckets"]:
                        acc += c
                        le_s = "+Inf" if le == "+Inf" else f"{le:.10g}"
                        extra = f'le="{le_s}"'
                        lines.append(
                            f"{name}_bucket{self._fmt_labels(lab, extra)}"
                            f" {acc:.10g}"
                        )
                    lines.append(
                        f"{name}_sum{self._fmt_labels(lab)} {s['sum']:.10g}"
                    )
                    lines.append(
                        f"{name}_count{self._fmt_labels(lab)} {s['count']:.10g}"
                    )
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# The bundle + process default
# ---------------------------------------------------------------------------


class Telemetry:
    """Clock + metric registry + tracer, as one installable unit.

    ``enabled=False`` builds the null plane: metrics and spans
    short-circuit (the uninstrumented baseline for the overhead gate).
    """

    SCHEMA_VERSION = 1

    def __init__(
        self,
        clock: Optional[Clock] = None,
        enabled: bool = True,
        max_series: int = 256,
        span_capacity: int = 256,
    ):
        from repro.obs.tracing import Tracer  # cycle-free: tracing imports nothing from here at module top except types

        self.clock = clock if clock is not None else Clock()
        self.enabled = bool(enabled)
        self.metrics = MetricRegistry(
            enabled=self.enabled, max_series=max_series
        )
        self.tracer = Tracer(
            self.clock, registry=self.metrics, capacity=span_capacity,
            enabled=self.enabled,
        )

    def snapshot(self, include_spans: int = 32) -> Dict:
        """The one stable JSON document every exporter renders from."""
        return {
            "schema_version": self.SCHEMA_VERSION,
            "enabled": self.enabled,
            "time_s": self.clock.time(),
            "metrics": self.metrics.snapshot(),
            "spans": [
                s.to_dict() for s in self.tracer.roots()[-include_spans:]
            ],
        }

    def snapshot_json(self, include_spans: int = 32) -> str:
        return json.dumps(self.snapshot(include_spans), indent=2)

    def to_prometheus(self) -> str:
        return self.metrics.to_prometheus()


_DEFAULT: Optional[Telemetry] = None
_DEFAULT_LOCK = threading.Lock()


def get_telemetry() -> Telemetry:
    """The process-wide telemetry every instrumented layer records into."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = Telemetry()
    return _DEFAULT


def set_telemetry(t: Telemetry) -> Telemetry:
    """Install ``t`` as the process default; returns the previous one."""
    global _DEFAULT
    prev = get_telemetry()
    _DEFAULT = t
    return prev


def reset_telemetry() -> Telemetry:
    """Fresh default telemetry (fresh metrics, fresh spans, real clock)."""
    return set_telemetry(Telemetry())


class use_telemetry:
    """Context manager installing ``t`` for a scope (tests / benches)."""

    def __init__(self, t: Telemetry):
        self.t = t
        self._prev: Optional[Telemetry] = None

    def __enter__(self) -> Telemetry:
        self._prev = set_telemetry(self.t)
        return self.t

    def __exit__(self, *exc) -> None:
        if self._prev is not None:
            set_telemetry(self._prev)
