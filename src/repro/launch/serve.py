"""Online feature + scoring service launcher (FeatInsight §3.1 step 4).

Boots the full serving stack: feature view -> online store (backfilled)
-> FeatureService -> ScoringService (feature vector + signature embedding
-> transformer -> score), then replays a synthetic request stream through
the BatchScheduler and reports latency percentiles + QPS.

  python -m repro.launch.serve --requests 512 --batch 64
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--history", type=int, default=8_000)
    ap.add_argument("--cards", type=int, default=128)
    args = ap.parse_args()

    import jax.numpy as jnp
    import numpy as np

    from repro.configs.featinsight_fraud import smoke_config
    from repro.core import (
        Col, FeatureRegistry, FeatureView, OnlineFeatureStore,
        range_window, rows_window, w_count, w_max, w_mean, w_std, w_sum,
    )
    from repro.data.synthetic import FRAUD_SCHEMA, fraud_stream
    from repro.models import build_model
    from repro.serve.service import FeatureService, ScoringService

    rng = np.random.default_rng(0)
    amt = Col("amount")
    w1h = range_window(3600, bucket=64)
    view = FeatureView(
        name="fraud_serving", schema=FRAUD_SCHEMA,
        features={
            "amt_sum_1h": w_sum(amt, w1h),
            "amt_mean_1h": w_mean(amt, w1h),
            "amt_std_1h": w_std(amt, w1h),
            "tx_count_1h": w_count(amt, w1h),
            "amt_max_1h": w_max(amt, w1h),
            "tx_count_20": w_count(amt, rows_window(20)),
        },
    )
    registry = FeatureRegistry()
    registry.register(view)

    print(f"[serve] backfilling {args.history} rows ...")
    hist, _ = fraud_stream(rng, args.history, num_cards=args.cards,
                           t_max=100_000)
    store = OnlineFeatureStore(view, num_keys=args.cards, capacity=256,
                               num_buckets=64, bucket_size=64)
    order = np.lexsort((hist["ts"], hist["card"]))
    store.ingest({c: v[order] for c, v in hist.items()})
    fsvc = FeatureService("fraud_svc", view, store, registry)

    cfg = smoke_config()
    model = build_model(cfg)
    params = model.init(0)
    table = jnp.asarray(rng.normal(0, 0.02, (1 << 12, cfg.d_model)),
                        jnp.float32)
    svc = ScoringService(fsvc, model, params, table)

    # request replay, fixed batch shape (compilation cached after batch 1)
    B = args.batch
    lat = []
    served = 0
    t_all = time.perf_counter()
    while served < args.requests:
        rows = {
            "card": rng.integers(0, args.cards, B).astype(np.int32),
            "ts": np.full(B, 100_001 + served, np.int32),
            "amount": rng.gamma(1.5, 60.0, B).astype(np.float32),
            "mcc": rng.integers(0, 32, B).astype(np.int32),
            "device": rng.integers(0, 8, B).astype(np.int32),
            "geo": rng.integers(0, 16, B).astype(np.int32),
        }
        t0 = time.perf_counter()
        scores = svc.handle(rows)
        lat.append(time.perf_counter() - t0)
        served += B
        assert scores.shape == (B,)
    dt = time.perf_counter() - t_all
    lat_ms = np.sort(np.array(lat[1:])) * 1e3  # drop compile batch
    print(f"[serve] {served} requests in {dt:.2f}s "
          f"({served / dt:.0f} QPS incl. compile)")
    if len(lat_ms):
        print(f"[serve] batch latency ms: p50={np.percentile(lat_ms, 50):.2f} "
              f"p95={np.percentile(lat_ms, 95):.2f} "
              f"max={lat_ms.max():.2f} "
              f"steady QPS={B * len(lat_ms) / (lat_ms.sum() / 1e3):.0f}")
    print(f"[serve] registry: {registry.service('fraud_svc')['view']} "
          f"v{registry.service('fraud_svc')['version']} deployed")


if __name__ == "__main__":
    main()
