"""Trip-count-corrected roofline accounting via unrolled layer probes.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count, so a scanned-L-layer model reports ~1/L of its real flops.  The
correction: lower UNROLLED (python-loop) variants with 1 and 2 layers under
the identical mesh/shardings; the difference isolates one layer's exact
per-device flops / bytes / collective-bytes, and the full step is
reconstructed linearly:

  train:   total = nm * (L * layer_grad + base_grad) + L * opt_layer + opt_base
           where {G1, G2} are grad-only probes and {O1, O2} full-step probes:
           layer_grad = G2 - G1, base_grad = 2*G1 - G2,
           opt_layer = (O2-G2) - (O1-G1), opt_base = (O1-G1) - opt_layer.
  prefill/decode: total = L * (P2 - P1) + (2*P1 - P2).

  griffin scales by super-blocks (probes at n_layers 3/6, tails at 5);
  encdec scales encoder and decoder independently (probes (1,1),(2,1),(1,2)).

Probes are cached by content key under experiments/probes/ — identical
probes shared across cells/meshes are compiled once.

Caveat (documented in EXPERIMENTS.md): probes measure a layer as compiled
standalone; the scanned full program may fuse slightly differently.  The
probe numbers are the honest per-layer costs; the real-cell compile is
still performed for memory_analysis and the collective schedule.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES, ShapeSpec, get_config
from repro.launch.mesh import cfg_for, make_production_mesh, rules_for
from repro.launch.roofline import CollectiveStats, parse_collectives
from repro.launch.specs import batch_partition, batch_specs, cache_partition, cache_specs
from repro.models import build_model
from repro.optim.adamw import adamw_init
from repro.sharding.api import use_rules
from repro.sharding.params import (
    opt_state_specs, param_specs, tree_named_shardings,
)
from repro.train.step import TrainSettings, build_train_step

PROBE_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "probes"

# bump when MODEL code changes alter lowered HLO (invalidates probe cache);
# rev history is logged in EXPERIMENTS.md SPerf.
PROBE_REV = 3


@dataclasses.dataclass
class Measure:
    flops: float
    bytes: float
    ici: float
    dcn: float

    def __add__(self, o):
        return Measure(self.flops + o.flops, self.bytes + o.bytes,
                       self.ici + o.ici, self.dcn + o.dcn)

    def __sub__(self, o):
        return Measure(self.flops - o.flops, self.bytes - o.bytes,
                       self.ici - o.ici, self.dcn - o.dcn)

    def __mul__(self, k):
        return Measure(self.flops * k, self.bytes * k, self.ici * k,
                       self.dcn * k)

    __rmul__ = __mul__

    def clamp(self):
        return Measure(max(self.flops, 0.0), max(self.bytes, 0.0),
                       max(self.ici, 0.0), max(self.dcn, 0.0))

    def to_dict(self):
        return dataclasses.asdict(self)


def _cost_get(ca, key):
    if isinstance(ca, dict):
        return float(ca.get(key, 0.0) or 0.0)
    if isinstance(ca, (list, tuple)) and ca and isinstance(ca[0], dict):
        return float(ca[0].get(key, 0.0) or 0.0)
    return 0.0


def _measure_compiled(compiled) -> Measure:
    ca = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    return Measure(
        flops=_cost_get(ca, "flops"),
        bytes=_cost_get(ca, "bytes accessed"),
        ici=float(coll.ici_bytes),
        dcn=float(coll.dcn_bytes),
    )


def _probe_key(**kw) -> str:
    blob = json.dumps(kw, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _cached(key: str) -> Optional[Measure]:
    p = PROBE_DIR / f"{key}.json"
    if p.exists():
        d = json.loads(p.read_text())
        return Measure(**d["measure"])
    return None


def _store(key: str, m: Measure, meta: Dict) -> None:
    PROBE_DIR.mkdir(parents=True, exist_ok=True)
    (PROBE_DIR / f"{key}.json").write_text(
        json.dumps({"measure": m.to_dict(), **meta}, indent=2)
    )


def _probe(arch: str, shape_name: str, *, multi_pod: bool, kind: str,
           layers: int, enc_layers: Optional[int], with_opt: bool,
           micro_batch: int, variant: str = "base") -> Measure:
    """Compile one probe and measure it (cached)."""
    key = _probe_key(arch=arch, shape=shape_name, multi_pod=multi_pod,
                     kind=kind, layers=layers, enc=enc_layers,
                     opt=with_opt, micro=micro_batch, rev=PROBE_REV,
                     **({"variant": variant} if variant != "base" else {}))
    hit = _cached(key)
    if hit is not None:
        return hit

    shape = SHAPES[shape_name]
    cfg = cfg_for(
        get_config(arch), multi_pod=multi_pod, variant=variant
    ).replace(n_layers=layers, unroll_layers=True)
    if enc_layers is not None:
        cfg = cfg.replace(n_encoder_layers=enc_layers)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, multi_pod=multi_pod, variant=variant)
    model = build_model(cfg)

    # probe shape: the real per-microbatch global batch
    pshape = ShapeSpec(shape.name, shape.seq_len, micro_batch, shape.kind)

    with mesh, use_rules(rules, mesh):
        params_sds = jax.eval_shape(lambda: model.init(0))
        p_specs = param_specs(params_sds, cfg, rules, mesh)
        p_shard = tree_named_shardings(mesh, p_specs)
        b_specs = batch_specs(cfg, pshape)
        b_shard = tree_named_shardings(
            mesh, batch_partition(cfg, pshape, rules, mesh)
        )
        if kind == "train":
            if with_opt:
                settings = TrainSettings(num_microbatches=1)
                step = build_train_step(model, cfg, settings)
                opt_sds = jax.eval_shape(adamw_init, params_sds)
                o_specs = opt_state_specs(p_specs, params_sds, mesh)
                o_shard = tree_named_shardings(mesh, o_specs)
                lowered = jax.jit(
                    step, in_shardings=(p_shard, o_shard, b_shard),
                ).lower(params_sds, opt_sds, b_specs)
            else:
                grad_fn = jax.grad(
                    lambda p, b: model.loss(p, b)[0]
                )
                lowered = jax.jit(
                    grad_fn, in_shardings=(p_shard, b_shard),
                ).lower(params_sds, b_specs)
        elif kind == "prefill":
            if cfg.family in ("dense", "moe", "encdec"):
                fn = lambda p, b: model.prefill(p, b, max_len=pshape.seq_len)
            else:
                fn = lambda p, b: model.prefill(p, b)
            lowered = jax.jit(
                fn, in_shardings=(p_shard, b_shard)
            ).lower(params_sds, b_specs)
        else:  # decode
            c_sds = cache_specs(cfg, pshape)
            c_shard = tree_named_shardings(
                mesh, cache_partition(cfg, pshape, rules, mesh)
            )
            lowered = jax.jit(
                lambda p, c, t: model.decode_step(p, c, t),
                in_shardings=(p_shard, c_shard, b_shard["tokens"]),
            ).lower(params_sds, c_sds, b_specs["tokens"])
        compiled = lowered.compile()

    m = _measure_compiled(compiled)
    _store(key, m, dict(arch=arch, shape=shape_name, multi_pod=multi_pod,
                        kind=kind, layers=layers, enc=enc_layers,
                        opt=with_opt, micro=micro_batch, variant=variant))
    return m


def corrected_measure(
    arch: str, shape_name: str, *, multi_pod: bool,
    num_microbatches: int = 1, variant: str = "base",
) -> Tuple[Measure, Dict]:
    """Reconstruct full-step per-device costs from unrolled probes."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    kind = shape.kind
    nm = num_microbatches if kind == "train" else 1
    micro = shape.global_batch // nm if kind == "train" else shape.global_batch

    detail: Dict = {"num_microbatches": nm, "micro_batch": micro}

    def probe(layers, enc=None, with_opt=False):
        return _probe(
            arch, shape_name, multi_pod=multi_pod, kind=kind,
            layers=layers, enc_layers=enc, with_opt=with_opt,
            micro_batch=micro, variant=variant,
        )

    if cfg.family == "griffin":
        ae = cfg.attn_every
        L_units = cfg.n_layers // ae          # super-blocks
        tails = cfg.n_layers - L_units * ae   # tail rec blocks
        if kind == "train":
            G1, G2 = probe(ae), probe(2 * ae)
            layer_g = (G2 - G1).clamp()
            base_g = (2 * G1 - G2).clamp()
            O1, O2 = probe(ae, with_opt=True), probe(2 * ae, with_opt=True)
            opt_layer = ((O2 - G2) - (O1 - G1)).clamp()
            opt_base = ((O1 - G1) - opt_layer).clamp()
            total = nm * (L_units * layer_g + base_g) \
                + L_units * opt_layer + opt_base
            if tails:
                T = (probe(ae + tails) - G1).clamp()
                total = total + nm * T
        else:
            P1, P2 = probe(ae), probe(2 * ae)
            layer = (P2 - P1).clamp()
            base = (2 * P1 - P2).clamp()
            total = L_units * layer + base
            if tails:
                total = total + (probe(ae + tails) - P1).clamp()
        detail["units"] = L_units
        return total, detail

    if cfg.family == "encdec":
        if kind == "train":
            G11 = probe(1, enc=1)
            Gd = (probe(2, enc=1) - G11).clamp()    # one decoder layer
            Ge = (probe(1, enc=2) - G11).clamp()    # one encoder layer
            base = (G11 - Gd - Ge).clamp()
            O11 = probe(1, enc=1, with_opt=True)
            Od = ((probe(2, enc=1, with_opt=True) - probe(2, enc=1)) - (O11 - G11)).clamp()
            Oe = ((probe(1, enc=2, with_opt=True) - probe(1, enc=2)) - (O11 - G11)).clamp()
            opt_base = ((O11 - G11) - Od - Oe).clamp()
            total = nm * (cfg.n_layers * Gd + cfg.n_encoder_layers * Ge + base) \
                + cfg.n_layers * Od + cfg.n_encoder_layers * Oe + opt_base
        elif kind == "prefill":
            P11 = probe(1, enc=1)
            Pd = (probe(2, enc=1) - P11).clamp()
            Pe = (probe(1, enc=2) - P11).clamp()
            base = (P11 - Pd - Pe).clamp()
            total = cfg.n_layers * Pd + cfg.n_encoder_layers * Pe + base
        else:  # decode touches only decoder layers
            P1, P2 = probe(1, enc=1), probe(2, enc=1)
            layer = (P2 - P1).clamp()
            base = (2 * P1 - P2).clamp()
            total = cfg.n_layers * layer + base
        return total, detail

    L = cfg.n_layers
    if kind == "train":
        G1, G2 = probe(1), probe(2)
        layer_g = (G2 - G1).clamp()
        base_g = (2 * G1 - G2).clamp()
        O1, O2 = probe(1, with_opt=True), probe(2, with_opt=True)
        opt_layer = ((O2 - G2) - (O1 - G1)).clamp()
        opt_base = ((O1 - G1) - opt_layer).clamp()
        total = nm * (L * layer_g + base_g) + L * opt_layer + opt_base
        detail["layer_grad_flops"] = layer_g.flops
    else:
        P1, P2 = probe(1), probe(2)
        layer = (P2 - P1).clamp()
        base = (2 * P1 - P2).clamp()
        total = L * layer + base
        detail["layer_flops"] = layer.flops
    return total, detail
