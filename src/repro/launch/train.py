"""Production training launcher.

Two modes:

* ``--dry-run`` — lower + compile the full assigned config on the
  production mesh (delegates to repro.launch.dryrun; needs no hardware).
* live mode — run real steps on whatever devices exist (CPU: the smoke
  config; TPU pod: the full config), with manifest checkpoints,
  checkpoint/restart on failure, and straggler monitoring.

Examples:
  python -m repro.launch.train --arch qwen3-32b --shape train_4k --dry-run
  python -m repro.launch.train --arch qwen3-32b --smoke --steps 20 \
      --ckpt-dir /tmp/ck --restore
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--restore", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        # dryrun must own process start (XLA_FLAGS before jax import) —
        # re-exec through its module entry point.
        import os
        import subprocess
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", args.arch, "--shape", args.shape, "--force",
        ]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd, env=dict(os.environ)))

    import jax
    import numpy as np

    from repro.ckpt.manifest import CheckpointManager
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.synthetic import lm_stream
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import TrainSettings, build_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"devices={len(jax.devices())}")

    settings = TrainSettings(
        num_microbatches=args.microbatches,
        grad_dtype="float32" if args.smoke else "bfloat16",
        opt=AdamWConfig(warmup_steps=min(20, args.steps),
                        decay_steps=args.steps),
    )
    step_fn = jax.jit(build_train_step(model, cfg, settings),
                      donate_argnums=(0, 1))

    params = model.init(0)
    opt = adamw_init(params)
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.restore and (latest := mgr.latest_step()) is not None:
        tpl = {"params": params, "opt": opt}
        restored = mgr.restore(latest, like=tpl)
        params, opt = restored["params"], restored["opt"]
        start = latest + 1
        print(f"[train] restored checkpoint step {latest}")

    rng = np.random.default_rng(0)
    stream = lm_stream(rng, args.batch, args.seq, cfg.vocab)
    if cfg.family == "encdec" or cfg.frontend is not None:
        base_stream = stream

        def with_frontend():
            frng = np.random.default_rng(1)
            for b in base_stream:
                if cfg.family == "encdec":
                    b["frames"] = frng.normal(
                        size=(args.batch, args.seq, cfg.d_model)
                    ).astype(np.float32)
                else:
                    b["frontend_embeds"] = frng.normal(
                        size=(args.batch, cfg.frontend_len, cfg.d_model)
                    ).astype(np.float32)
                yield b
        stream = with_frontend()

    t0 = time.perf_counter()
    times = []
    for step in range(start, args.steps):
        ts = time.perf_counter()
        batch = next(stream)
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        times.append(time.perf_counter() - ts)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt}, blocking=False)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({times[-1]*1e3:.0f} ms)")
    if mgr:
        mgr.save(args.steps - 1, {"params": params, "opt": opt},
                 blocking=True)
        mgr.wait()
    dt = time.perf_counter() - t0
    tok = (args.steps - start) * args.batch * args.seq
    print(f"[train] {args.steps - start} steps in {dt:.1f}s "
          f"({tok / max(dt, 1e-9):.0f} tok/s)")


if __name__ == "__main__":
    main()
