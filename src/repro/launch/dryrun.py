import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms.

The two lines above MUST run before any other import (jax locks the
device count at first init) — do not move them.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--force]

Results are cached as JSON under experiments/dryrun/ (one file per cell),
so an interrupted sweep resumes where it left off.
"""

import argparse
import json
import pathlib
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (
    SHAPES, ShapeSpec, cells, get_config, shape_applicable,
)
from repro.launch.mesh import cfg_for, make_production_mesh, rules_for
from repro.launch.roofline import (
    CollectiveStats, model_flops, parse_collectives, roofline_terms,
)
from repro.launch.specs import (
    batch_partition, batch_specs, cache_partition, cache_specs,
)
from repro.models import build_model
from repro.optim.adamw import adamw_init
from repro.sharding.api import use_rules
from repro.sharding.params import (
    opt_state_specs, param_specs, tree_named_shardings,
)
from repro.train.step import TrainSettings, build_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _num_microbatches(cfg, shape: ShapeSpec, mesh) -> int:
    """One sequence per data shard per microbatch (activation budget)."""
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    nm = max(1, shape.global_batch // data)
    while shape.global_batch % nm != 0:
        nm -= 1
    return nm


def _cost_get(ca, key: str) -> float:
    if isinstance(ca, dict):
        return float(ca.get(key, 0.0) or 0.0)
    if isinstance(ca, (list, tuple)) and ca and isinstance(ca[0], dict):
        return float(ca[0].get(key, 0.0) or 0.0)
    return 0.0


def run_cell(
    arch: str, shape_name: str, *, multi_pod: bool = False,
    extra: Optional[Dict] = None, return_lowered: bool = False,
    skip_probe: bool = False, variant: str = "base",
) -> Dict:
    """Lower + compile one cell; return the roofline record."""
    shape = SHAPES[shape_name]
    cfg = cfg_for(get_config(arch), multi_pod=multi_pod, variant=variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, multi_pod=multi_pod, variant=variant)
    if extra:
        rules.update(extra.get("rules", {}))
    model = build_model(cfg)
    n_devices = 512 if multi_pod else 256
    record: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "n_devices": n_devices,
        "variant": variant,
    }

    t0 = time.time()
    with mesh, use_rules(rules, mesh):
        params_sds = jax.eval_shape(lambda: model.init(0))
        p_specs = param_specs(params_sds, cfg, rules, mesh)
        p_shard = tree_named_shardings(mesh, p_specs)

        if shape.kind == "train":
            nm = extra.get("num_microbatches") if extra else None
            nm = nm or _num_microbatches(cfg, shape, mesh)
            record["num_microbatches"] = nm
            settings = TrainSettings(num_microbatches=nm)
            step = build_train_step(model, cfg, settings)
            opt_sds = jax.eval_shape(adamw_init, params_sds)
            o_specs = opt_state_specs(p_specs, params_sds, mesh)
            o_shard = tree_named_shardings(mesh, o_specs)
            b_specs = batch_specs(cfg, shape)
            b_shard = tree_named_shardings(
                mesh, batch_partition(cfg, shape, rules, mesh)
            )
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_sds, opt_sds, b_specs)
        elif shape.kind == "prefill":
            b_specs = batch_specs(cfg, shape)
            b_shard = tree_named_shardings(
                mesh, batch_partition(cfg, shape, rules, mesh)
            )
            if cfg.family in ("dense", "moe", "encdec"):
                fn = lambda p, b: model.prefill(p, b, max_len=shape.seq_len)
            else:
                fn = lambda p, b: model.prefill(p, b)
            lowered = jax.jit(
                fn, in_shardings=(p_shard, b_shard),
            ).lower(params_sds, b_specs)
        else:  # decode
            c_sds = cache_specs(cfg, shape)
            c_shard = tree_named_shardings(
                mesh, cache_partition(cfg, shape, rules, mesh)
            )
            b_specs = batch_specs(cfg, shape)
            b_shard = tree_named_shardings(
                mesh, batch_partition(cfg, shape, rules, mesh)
            )
            fn = lambda p, c, t: model.decode_step(p, c, t)
            lowered = jax.jit(
                fn,
                in_shardings=(p_shard, c_shard, b_shard["tokens"]),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ).lower(params_sds, c_sds, b_specs["tokens"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis()
    flops = _cost_get(ca, "flops")
    bytes_acc = _cost_get(ca, "bytes accessed")
    try:
        ma = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        }
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)}

    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    record["collectives"] = {
        "counts": coll.counts,
        "bytes_by_kind": coll.bytes_by_kind,
        "ici_bytes": coll.ici_bytes,
        "dcn_bytes": coll.dcn_bytes,
    }
    # raw cost_analysis counts while-loop (scan) bodies ONCE -> kept for
    # reference; the roofline terms use the probe-corrected totals below.
    record["raw_scan_counted_once"] = {
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
    }
    if skip_probe:
        record["collectives_raw"] = record["collectives"]
        if return_lowered:
            return record, lowered
        return record

    from repro.launch.probe import corrected_measure
    corrected, probe_detail = corrected_measure(
        arch, shape_name, multi_pod=multi_pod,
        num_microbatches=record.get("num_microbatches", 1),
        variant=variant,
    )
    cstats = CollectiveStats(
        counts=coll.counts, bytes_by_kind=coll.bytes_by_kind,
        ici_bytes=int(corrected.ici), dcn_bytes=int(corrected.dcn),
    )
    terms = roofline_terms(corrected.flops, corrected.bytes, cstats)
    mf = model_flops(cfg, shape, shape.kind)
    record.update(
        flops_per_device=corrected.flops,
        bytes_per_device=corrected.bytes,
        probe_detail=probe_detail,
        model_flops_global=mf,
        model_flops_per_device=mf / n_devices,
        useful_flops_ratio=(
            (mf / n_devices) / corrected.flops if corrected.flops else 0.0
        ),
        roofline=terms,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        hlo_bytes=len(hlo),
    )
    if return_lowered:
        return record, lowered
    return record


def cell_path(arch: str, shape_name: str, multi_pod: bool,
              tag: str = "") -> pathlib.Path:
    mesh = "multi" if multi_pod else "single"
    suffix = f"-{tag}" if tag else ""
    return OUT_DIR / f"{arch}__{shape_name}__{mesh}{suffix}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="base",
                    help="sharding variant from launch.mesh.VARIANTS")
    ap.add_argument("--tag", default="", help="file tag (defaults to variant)")
    args = ap.parse_args()
    if args.variant != "base" and not args.tag:
        args.tag = args.variant

    OUT_DIR.mkdir(parents=True, exist_ok=True)

    todo = []
    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for arch, shape, ok, reason in cells():
            for mp in meshes:
                todo.append((arch, shape, mp, ok, reason))
    else:
        assert args.arch and args.shape
        ok, reason = shape_applicable(args.arch, args.shape)
        todo.append((args.arch, args.shape, args.multi_pod, ok, reason))

    n_ok = n_skip = n_fail = 0
    for arch, shape, mp, ok, reason in todo:
        path = cell_path(arch, shape, mp, args.tag)
        if path.exists() and not args.force:
            print(f"[cached] {path.name}")
            continue
        if not ok:
            rec = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "skipped": True, "reason": reason,
            }
            path.write_text(json.dumps(rec, indent=2))
            print(f"[skip]   {arch} x {shape}: {reason.split(':')[0]}")
            n_skip += 1
            continue
        print(f"[run]    {arch} x {shape} mesh={'2x16x16' if mp else '16x16'}"
              f" variant={args.variant}")
        try:
            rec = run_cell(arch, shape, multi_pod=mp, variant=args.variant)
            path.write_text(json.dumps(rec, indent=2))
            r = rec["roofline"]
            print(
                f"         ok: compile={rec['compile_s']}s "
                f"flops/dev={rec['flops_per_device']:.3e} "
                f"terms(c/m/coll)={r['compute_s']:.4f}/"
                f"{r['memory_s']:.4f}/{r['collective_s']:.4f}s "
                f"dominant={r['dominant']}"
            )
            n_ok += 1
        except Exception as e:
            n_fail += 1
            err = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "error": str(e)[:2000],
                "traceback": traceback.format_exc()[-4000:],
            }
            path.with_suffix(".error.json").write_text(json.dumps(err, indent=2))
            print(f"         FAIL: {str(e)[:300]}")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
