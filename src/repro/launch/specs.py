"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns the exact pytree of inputs the step
function lowers against — weak-type-correct, shardable, no device
allocation (caches are built with jax.eval_shape over the real cache
constructors, so dry-run cache structure can never drift from the model).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeSpec
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.models import kvcache as kvc

__all__ = ["input_specs", "batch_specs", "cache_specs", "batch_partition",
           "cache_partition", "decode_cache_len"]


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def decode_cache_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Effective cache length for decode cells (windowed archs truncate)."""
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, shape.seq_len)
    return shape.seq_len


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        if cfg.family == "encdec":
            out["frames"] = _sds((B, S, cfg.d_model), jnp.float32)
        elif cfg.frontend is not None:
            out["frontend_embeds"] = _sds(
                (B, cfg.frontend_len, cfg.d_model), jnp.float32
            )
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "encdec":
            out["frames"] = _sds((B, S, cfg.d_model), jnp.float32)
        elif cfg.frontend is not None:
            out["frontend_embeds"] = _sds(
                (B, cfg.frontend_len, cfg.d_model), jnp.float32
            )
        return out
    # decode: one new token per sequence
    return {"tokens": _sds((B, 1), jnp.int32)}


def batch_partition(
    cfg: ModelConfig, shape: ShapeSpec, rules: Dict,
    mesh: Optional[Mesh] = None,
) -> Dict:
    batch_ax = rules.get("batch")
    seq_ax = rules.get("seq_shard") if (
        shape.kind == "prefill" and shape.global_batch == 1
    ) else None

    def spec_of(sds):
        nd = sds.ndim
        parts = [_guard(sds.shape[0], batch_ax, mesh)] + [None] * (nd - 1)
        if nd >= 2 and seq_ax is not None:
            parts[1] = _guard(sds.shape[1], seq_ax, mesh)  # SP, batch-1 prefill
        return P(*parts)

    return {k: spec_of(v) for k, v in batch_specs(cfg, shape).items()}


# ---------------------------------------------------------------------------
# caches (decode cells)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """SDS pytree of the decode cache at context length shape.seq_len."""
    B = shape.global_batch
    model = build_model(cfg)
    if cfg.family in ("dense", "moe"):
        W = decode_cache_len(cfg, shape)
        if cfg.sliding_window is not None:
            return jax.eval_shape(
                lambda: kvc.sliding_kv_init(cfg, B, W)
            )
        return jax.eval_shape(lambda: kvc.full_kv_init(cfg, B, W))
    if cfg.family == "rwkv":
        return jax.eval_shape(lambda: model.init_state(B))
    if cfg.family == "griffin":
        return jax.eval_shape(lambda: model.init_state(B))
    if cfg.family == "encdec":
        S = shape.seq_len

        def mk():
            cache = kvc.full_kv_init(cfg, B, S)
            return {
                "self": cache,
                "cross_k": jnp.zeros(
                    (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd), cfg.cdtype
                ),
                "cross_v": jnp.zeros(
                    (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd), cfg.cdtype
                ),
                "enc_positions": jnp.zeros((B, S), jnp.int32),
            }

        return jax.eval_shape(mk)
    raise ValueError(cfg.family)


def _guard(dim: int, axes, mesh: Optional[Mesh]):
    if axes is None or mesh is None:
        return None
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n = 1
    for a in axes_t:
        n *= mesh.shape[a]
    return axes if (n > 1 and dim % n == 0) or n == 1 else None


def cache_partition(cfg: ModelConfig, shape: ShapeSpec, rules: Dict,
                    mesh: Optional[Mesh]):
    """PartitionSpec pytree matching cache_specs.

    KV tensors (L, B, S, Hkv, hd): batch on the data axis, head_dim lanes
    on the model axis ("kv_head_dim" rule) — kv-head counts (8) don't
    divide the 16-way model axis but head_dim (64..256) always does, and
    sharding the contraction lane keeps attention collective-free except
    for a small per-layer psum of scores.
    Recurrent states shard batch + feature lanes where divisible.
    """
    batch_ax = rules.get("batch")
    lane_ax = rules.get("kv_head_dim")
    # flash-decoding seq sharding applies only to full (non-sliding) KV
    # caches: dense/moe/encdec.  Sliding windows (mixtral, griffin) scatter
    # at pos % W, which GSPMD turns into a full rematerialization when the
    # scattered dim is sharded; recurrent states have no seq dim at all.
    full_kv = cfg.family in ("dense", "moe", "encdec") and (
        cfg.sliding_window is None
    )
    seq_ax = rules.get("kv_seq") if full_kv else None

    def spec_of(sds):
        shp = sds.shape
        nd = sds.ndim
        if nd == 5:   # (L, B, S|W, Hkv, hd)
            # flash-decoding layout: sequence sharded over the model axis
            # (partial softmax stats psum, KB-sized) when "kv_seq" is set;
            # otherwise the head_dim lane (psum of scores).
            return P(None, _guard(shp[1], batch_ax, mesh),
                     _guard(shp[2], seq_ax, mesh), None,
                     None if seq_ax else _guard(shp[4], lane_ax, mesh))
        if nd == 4:   # griffin conv (NS, B, CW-1, R) / rwkv (L,B,H,...)
            return P(None, _guard(shp[1], batch_ax, mesh), None, None)
        if nd == 3:   # (L, B, D) shift states / (NS, B, R) / k_pos (NS,B,W)
            last = _guard(shp[2], lane_ax, mesh) if shp[2] >= 256 else None
            return P(None, _guard(shp[1], batch_ax, mesh), last)
        if nd == 2:   # (B, W) k_pos / (B, S) positions / (B, CW-1...)
            return P(_guard(shp[0], batch_ax, mesh), None)
        if nd == 1:   # pos (B,)
            return P(_guard(shp[0], batch_ax, mesh))
        return P()

    specs = cache_specs(cfg, shape)

    def map_spec(sds):
        return spec_of(sds)

    tree = jax.tree.map(map_spec, specs)
    if cfg.family == "rwkv":
        # wkv state (L, B, H, hd, hd): shard batch; head dim lanes replicate
        tree["wkv"] = P(None, _guard(shape.global_batch, batch_ax, mesh),
                        None, None, None)
    return tree


# ---------------------------------------------------------------------------
# combined
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Everything the step function lowers against, except params/opt."""
    out = {"batch": batch_specs(cfg, shape)}
    if shape.kind == "decode":
        out["cache"] = cache_specs(cfg, shape)
    return out
