"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests and benches see 1 CPU device; only
dryrun.py sets XLA_FLAGS for 512 host devices before any jax import).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "rules_for", "cfg_for", "VARIANTS"]

# Perf-iteration variants (EXPERIMENTS.md §Perf): named rule overrides so
# each hypothesis is a one-flag dry-run away and probes cache per-variant.
VARIANTS = {
    "base": {},
    # flash-decoding cache layout: shard the KV sequence over the model
    # axis; softmax-stat psums (KB) replace the K/V all-gather (GB)
    "kvseq": {"kv_seq": "model"},
    # group-local MoE dispatch (+ kvseq): G = data-shard count so routing
    # sort/scatter never crosses shards; EP exchange becomes an all-to-all
    "moegroup": {"kv_seq": "model"},
    # ZeRO-2-style sharded gradient accumulation: per-microbatch gradient
    # reduction becomes a reduce-scatter into a (pod,data)-sharded
    # accumulator instead of a full all-reduce
    "gradrs": {"kv_seq": "model", "grad_accum": ("pod", "data")},
    "gradrs1p": {"kv_seq": "model", "grad_accum": ("data",)},
}

# config-level overrides per variant (applied by dryrun/probe via cfg_for)
CFG_VARIANTS = {
    "moegroup": {"moe_groups": 16},
}


def cfg_for(cfg, *, multi_pod: bool = False, variant: str = "base"):
    over = dict(CFG_VARIANTS.get(variant, {}))
    if "moe_groups" in over and multi_pod:
        over["moe_groups"] = 32  # pod x data shards
    return cfg.replace(**over) if over else cfg


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def rules_for(cfg, *, multi_pod: bool = False, variant: str = "base"):
    """Logical-axis rules adjusted per architecture.

    MoE: 64 experts (moonshot) -> expert-parallel on "model"; 8 experts
    (mixtral) -> experts replicated, expert FFN tensor-sharded on d_ff.
    """
    from repro.sharding.api import DEFAULT_RULES, MULTI_POD_RULES

    rules = dict(MULTI_POD_RULES if multi_pod else DEFAULT_RULES)
    rules["fused_heads"] = "model"
    model_size = 16
    if cfg.family == "moe":
        if cfg.num_experts % model_size == 0:
            rules["experts"] = "model"
            rules["expert_ff"] = None
        else:
            rules["experts"] = None
            rules["expert_ff"] = "model"
    rules.update(VARIANTS[variant])
    return rules
