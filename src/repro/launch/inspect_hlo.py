"""HLO collective inspector — the perf-loop's profiler substitute.

Lowers ONE cell (optionally with reduced layer count and rule overrides)
on the production mesh and prints every collective op with its shape,
byte count, and source line, largest first.  This is how hypotheses in
EXPERIMENTS.md §Perf get grounded: the dry-run roofline says WHICH term
dominates; this says WHY.

  python -m repro.launch.inspect_hlo --arch qwen3-32b --shape decode_32k \
      --layers 2 [--multi-pod] [--rule kv_head_dim=None] [--top 20]
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import re

_SIZES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def shape_bytes(sig: str) -> int:
    """'bf16[8,4096,8,8]{...}' -> bytes (first shape in a possibly-tuple)."""
    total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _SIZES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _SIZES[dt]
    return total


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--layers", type=int, default=0,
                    help="override n_layers (0 = full)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rule", action="append", default=[],
                    help="override sharding rule, e.g. kv_head_dim=None")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--bytes-by-op", action="store_true")
    ap.add_argument("--cfg", action="append", default=[],
                    help="override config field, e.g. moe_groups=16")
    args = ap.parse_args()

    import jax
    from repro.configs.registry import SHAPES, get_config
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh, rules_for

    cfg = get_config(args.arch)
    if args.layers:
        over = {"n_layers": args.layers}
        if cfg.family == "encdec":
            over["n_encoder_layers"] = args.layers
        cfg = cfg.replace(**over)

    rules = rules_for(cfg, multi_pod=args.multi_pod)
    for r in args.rule:
        k, v = r.split("=")
        rules[k] = None if v in ("None", "none", "") else (
            tuple(v.split("+")) if "+" in v else v
        )

    for c in args.cfg:
        k, v = c.split("=")
        cfg = cfg.replace(**{k: int(v) if v.lstrip("-").isdigit() else v})

    # lower via the dryrun cell machinery but with our cfg/rules.
    # NB: dryrun imported get_config into its own namespace -- patch BOTH.
    import repro.configs.registry as registry
    orig = registry.get_config
    registry.get_config = lambda a: cfg
    dr.get_config = lambda a: cfg
    try:
        extra = {"rules": rules}
        if args.microbatches:
            extra["num_microbatches"] = args.microbatches
        rec, lowered = dr.run_cell(
            args.arch, args.shape, multi_pod=args.multi_pod,
            extra=extra, return_lowered=True, skip_probe=True,
        )
    finally:
        registry.get_config = orig
        dr.get_config = orig

    hlo = lowered.compile().as_text()

    if args.bytes_by_op:
        # rank ALL ops by output bytes (coarse where-does-memory-go view)
        allops = []
        for line in hlo.splitlines():
            ls = line.strip()
            m = re.match(r"%?(\S+) = ((?:\()?[a-z0-9]+\[[^=]*?) ([a-z\-]+)\(",
                         ls)
            if not m:
                continue
            b = shape_bytes(m.group(2))
            if b < (1 << 20):
                continue
            src = ""
            mm = re.search(r'op_name="([^"]+)"', ls)
            if mm:
                src = mm.group(1)[-70:]
            allops.append((b, m.group(3), m.group(2)[:48], src))
        allops.sort(reverse=True)
        print(f"\n== ops by output bytes (>1MiB): {len(allops)} ==")
        agg = {}
        for b, kind, sig, src in allops:
            agg[kind] = agg.get(kind, 0) + b
        for k, v in sorted(agg.items(), key=lambda x: -x[1]):
            print(f"  total {v:>14.3e}  {k}")
        for b, kind, sig, src in allops[: args.top]:
            print(f"  {b:>14.3e}  {kind:<22s} {sig:<50s} {src}")

    ops = []
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"%?\S+ = (\S+) (all-gather|all-reduce|reduce-scatter|"
                     r"all-to-all|collective-permute)", ls)
        if m:
            b = shape_bytes(m.group(1))
            kind = m.group(2)
            src = ""
            mm = re.search(r'op_name="([^"]+)"', ls)
            if mm:
                src = mm.group(1)[-90:]
            ops.append((b, kind, m.group(1)[:60], src))
    ops.sort(reverse=True)
    total = sum(b for b, *_ in ops)
    print(f"\n== collectives: {len(ops)} ops, {total:.3e} bytes total "
          f"(layers={args.layers or 'full'}) ==")
    for b, kind, sig, src in ops[: args.top]:
        print(f"  {b:>14.3e}  {kind:<20s} {sig:<62s} {src}")


if __name__ == "__main__":
    main()
