"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds-per-step per chip:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS          (197 TF bf16, v5e)
  memory     = HLO_bytes_per_device / HBM_BW              (819 GB/s)
  collective = ici_bytes / ICI_BW + dcn_bytes / DCN_BW    (50 GB/s/link;
               cross-pod counted at DCN_BW — assumed ICI/8, documented)

``cost_analysis()`` reports per-partition (per-device) flops/bytes for the
SPMD-partitioned module (verified empirically).  Collective bytes are NOT
in cost_analysis: we parse the optimized HLO and sum operand bytes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, classifying each by whether its replica group crosses
the pod boundary (device id // 256).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 per chip, TPU v5e-class
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link
DCN_BW = ICI_BW / 8      # assumption for cross-pod links (documented)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]
    ici_bytes: int
    dcn_bytes: int

    def total_bytes(self) -> int:
        return self.ici_bytes + self.dcn_bytes


def parse_collectives(hlo_text: str, pod_size: int = 256) -> CollectiveStats:
    counts: Dict[str, int] = {}
    bytes_by_kind: Dict[str, int] = {}
    ici = 0
    dcn = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        result_type = m.group(1) or m.group(2)
        nbytes = _shape_bytes(result_type)
        if nbytes == 0:
            continue
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + nbytes

        crosses_pod = False
        gm = _GROUPS_RE.search(line)
        if gm:
            # first group is representative (SPMD groups are uniform)
            first = gm.group(1).split("},{")[0]
            ids = [int(x) for x in re.findall(r"\d+", first)]
            if ids and (max(ids) // pod_size) != (min(ids) // pod_size):
                crosses_pod = True
        else:
            pm = _PAIRS_RE.search(line)
            if pm:
                ids = [int(x) for x in re.findall(r"\d+", pm.group(1))[:8]]
                if any(
                    (a // pod_size) != (b // pod_size)
                    for a, b in zip(ids[::2], ids[1::2])
                ):
                    crosses_pod = True
        if crosses_pod:
            dcn += nbytes
        else:
            ici += nbytes
    return CollectiveStats(counts, bytes_by_kind, ici, dcn)


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    coll: CollectiveStats,
) -> Dict[str, float]:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = coll.ici_bytes / ICI_BW + coll.dcn_bytes / DCN_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "collective_ici_bytes": coll.ici_bytes,
        "collective_dcn_bytes": coll.dcn_bytes,
        "dominant": dominant,
    }


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS: 6ND train, 2ND forward-only (N = active params)."""
    n_active = cfg.param_count(active_only=True)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence (KV-cache reads dominate; the flops
    # term counts the matmul work only)
    return 2.0 * n_active * shape.global_batch
