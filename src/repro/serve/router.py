"""Sharded serving front-end — routing + micro-batching over shard meshes.

The serving plane's request dataflow (FeatInsight's online engine, scaled
out the way OpenMLDB partitions online table state across nodes):

    submit(row) ──> BatchScheduler          (coalesce: max_batch / max_wait_us)
        │
        ▼ next_batch()  — padded shape bucket + __valid__ mask
    FeatureService.request
        │
        ▼ ShardedOnlineStore.query          (one fused program on the mesh)
        │     host: bucket rows by shard = key % S, pad each shard's rows
        │     to a shared power-of-two bucket, device_put with
        │     NamedSharding('shard'); device: vmapped per-shard query
        │     (ring + bucket pre-agg + secondary rings, zero cross-shard
        │     collectives); host: scatter answers back to request order
        ▼
    per-request feature rows (submission order)

:class:`ShardRouter` owns that loop and the serving-side observability:
per-shard request occupancy (skew monitoring) and the service's latency
percentiles.  It is store-agnostic — a single-device store degrades to
S=1 — so services opt into sharding purely via
``FeatureService.build(..., sharded=True)``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.serve.service import BatchScheduler, FeatureService

__all__ = ["ShardRouter"]


class ShardRouter:
    """Micro-batching front-end for a (sharded) feature service.

    ``pump()`` moves one batch through the pipeline; ``drain()`` pumps
    until the queue is empty (flushing any open coalescing window).
    Responses come back as per-request feature rows in submission order.
    """

    def __init__(
        self,
        service: FeatureService,
        scheduler: Optional[BatchScheduler] = None,
        ingest: bool = True,
    ):
        self.service = service
        self.scheduler = scheduler if scheduler is not None else BatchScheduler()
        self.ingest = ingest
        self.num_shards = int(getattr(service.store, "num_shards", 1))
        # per-shard request counts — the serving-skew histogram
        self.shard_requests = np.zeros(self.num_shards, np.int64)

    def submit(self, row: Dict, now_us: Optional[int] = None) -> None:
        self.scheduler.submit(row, now_us=now_us)

    def pump(
        self, now_us: Optional[int] = None, flush: bool = False
    ) -> Optional[Dict[str, np.ndarray]]:
        """Serve one coalesced batch; None if nothing is ready yet."""
        batch = self.scheduler.next_batch(now_us=now_us, flush=flush)
        if batch is None:
            return None
        valid = np.asarray(batch["__valid__"], bool)
        out = self.service.request(batch, ingest=self.ingest)
        key_col = self.service.view.schema.key
        store = self.service.store
        if hasattr(store, "shard_of"):
            shard = store.shard_of(np.asarray(batch[key_col])[valid])
            self.shard_requests += np.bincount(
                shard, minlength=self.num_shards
            )
        else:
            self.shard_requests[0] += int(valid.sum())
        return {k: np.asarray(v)[valid] for k, v in out.items()}

    def drain(
        self, now_us: Optional[int] = None
    ) -> Optional[Dict[str, np.ndarray]]:
        """Flush everything queued; concatenated rows in submission order."""
        outs: List[Dict[str, np.ndarray]] = []
        while True:
            got = self.pump(now_us=now_us, flush=True)
            if got is None:
                break
            outs.append(got)
        if not outs:
            return None
        return {
            k: np.concatenate([o[k] for o in outs]) for k in outs[0]
        }

    def shard_histogram(self) -> np.ndarray:
        """Requests served per shard (copy)."""
        return self.shard_requests.copy()
