"""Sharded serving front-end — routing + micro-batching over shard meshes.

The serving plane's request dataflow (FeatInsight's online engine, scaled
out the way OpenMLDB partitions online table state across nodes):

    submit(row) ──> BatchScheduler          (coalesce: max_batch / max_wait_us)
        │
        ▼ next_batch()  — padded shape bucket + __valid__ mask
    FeatureService.request
        │
        ▼ ShardedOnlineStore.query          (one fused program on the mesh)
        │     host: bucket rows by shard = perm(key) % S, pad each shard's
        │     rows to a shared power-of-two bucket, device_put with
        │     NamedSharding('shard'); device: vmapped per-shard query
        │     (ring + bucket pre-agg + secondary rings, zero cross-shard
        │     collectives); host: scatter answers back to request order
        ▼
    per-request feature rows (submission order)

:class:`ShardRouter` owns that loop and the serving-side observability:
per-shard request occupancy (skew monitoring) and the service's latency
percentiles.  It is store-agnostic — a single-device store degrades to
S=1 — so services opt into sharding purely via
``FeatureService.build(..., sharded=True)``.

**Multi-scenario routing** (``FeatureService.build_multi``): requests are
submitted with a scenario tag and coalesce in ONE queue; each popped batch
is partitioned by scenario on the host, and every scenario group runs
through its own compiled program against the shared sharded state — so
rows are effectively bucketed by (scenario, shard), padded per bucket
inside the store, and scattered back to request order per scenario.
Occupancy is tracked per (scenario, shard) in
:meth:`ShardRouter.scenario_shard_histogram`.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs import get_telemetry
from repro.serve.service import (
    BatchScheduler,
    FeatureService,
    MultiScenarioService,
)

__all__ = ["ShardRouter"]

_SCENARIO_COL = "__scenario__"


class ShardRouter:
    """Micro-batching front-end for a (sharded, multi-scenario) service.

    ``pump()`` moves one batch through the pipeline; ``drain()`` pumps
    until the queue is empty (flushing any open coalescing window).
    Responses come back as per-request feature rows in submission order —
    for a multi-scenario service, per scenario:
    ``{scenario: {feature: rows-in-submission-order}}``.
    """

    def __init__(
        self,
        service: FeatureService,
        scheduler: Optional[BatchScheduler] = None,
        ingest: bool = True,
    ):
        self.service = service
        self.scheduler = scheduler if scheduler is not None else BatchScheduler()
        self.ingest = ingest
        self.num_shards = int(getattr(service.store, "num_shards", 1))
        self.scenarios: Optional[List[str]] = (
            list(service.scenarios)
            if isinstance(service, MultiScenarioService)
            else None
        )
        # per-shard request counts — the serving-skew histogram (aggregate
        # over scenarios), plus the per-(scenario, shard) breakdown for
        # multi-scenario deployments
        self.shard_requests = np.zeros(self.num_shards, np.int64)
        self.scenario_shard_requests: Dict[str, np.ndarray] = {
            s: np.zeros(self.num_shards, np.int64)
            for s in (self.scenarios or ())
        }

    def _sync_scenarios(self) -> None:
        """Pick up scenarios hot-deployed onto the service since this
        router was built (``MultiScenarioService.hot_deploy``): the
        scenario list and its per-(scenario, shard) histograms follow the
        live plane."""
        if self.scenarios is None:
            return
        live = list(self.service.scenarios)
        if live != self.scenarios:
            self.scenarios = live
            for s in live:
                self.scenario_shard_requests.setdefault(
                    s, np.zeros(self.num_shards, np.int64)
                )

    def submit(
        self,
        row: Dict,
        now_us: Optional[int] = None,
        scenario: Optional[str] = None,
    ) -> None:
        """Queue one request row; multi-scenario services require the
        ``scenario`` tag (which view answers this row)."""
        self._sync_scenarios()
        if self.scenarios is not None:
            if scenario is None:
                raise ValueError(
                    "multi-scenario router: submit(..., scenario=) required "
                    f"(one of {self.scenarios})"
                )
            if scenario not in self.scenario_shard_requests:
                raise KeyError(
                    f"unknown scenario {scenario!r}; service has "
                    f"{self.scenarios}"
                )
            row = dict(row)
            row[_SCENARIO_COL] = scenario
        elif scenario is not None:
            raise ValueError(
                f"service {self.service.name!r} is single-scenario; "
                "submit() takes no scenario tag"
            )
        self.scheduler.submit(row, now_us=now_us)

    def _count_shards(
        self,
        keys: np.ndarray,
        valid: Optional[np.ndarray],
        scenario: Optional[str],
    ) -> None:
        """Fold one batch's keys into the skew histograms.

        The histograms count *requests*, never padding: filler rows repeat
        a real row's key, so counting them would inflate exactly the shard
        that real row routed to and skew reads as worse than it is.
        Filtering is structural — every call site hands the batch's
        ``__valid__`` mask (or None for an all-real batch) and the padded
        rows are dropped here; the plane's padding cost is reported
        explicitly by the ``padding_rows_total`` / ``padding_waste_ratio``
        telemetry instead of leaking into occupancy.
        """
        keys = np.asarray(keys)
        if valid is not None:
            keys = keys[np.asarray(valid, bool)[: len(keys)]]
        store = self.service.store
        if hasattr(store, "shard_of"):
            hist = np.bincount(
                store.shard_of(keys), minlength=self.num_shards
            )
        else:
            hist = np.zeros(self.num_shards, np.int64)
            hist[0] = len(keys)
        self.shard_requests += hist
        if scenario is not None:
            self.scenario_shard_requests[scenario] += hist
        c = get_telemetry().metrics.counter(
            "shard_dispatch_rows_total",
            "request rows dispatched per (scenario, shard)", "1",
            labels=("scenario", "shard"),
            max_series=1024,
        )
        for sh in np.nonzero(hist)[0]:
            c.inc(int(hist[sh]), scenario=scenario or "", shard=str(int(sh)))

    def pump(
        self, now_us: Optional[int] = None, flush: bool = False
    ) -> Optional[Dict[str, np.ndarray]]:
        """Serve one coalesced batch; None if nothing is ready yet."""
        self._sync_scenarios()
        batch = self.scheduler.next_batch(now_us=now_us, flush=flush)
        if batch is None:
            return None
        valid = np.asarray(batch["__valid__"], bool)
        get_telemetry().metrics.gauge(
            "batch_occupancy_ratio",
            "real rows / padded batch rows, last batch", "1",
            labels=("service",),
        ).set(
            float(valid.sum()) / max(len(valid), 1),
            service=self.service.name,
        )
        key_col = self.service.view.schema.key
        if self.scenarios is None:
            out = self.service.request(batch, ingest=self.ingest)
            self._count_shards(np.asarray(batch[key_col]), valid, None)
            return {k: np.asarray(v)[valid] for k, v in out.items()}
        # multi-scenario: partition the popped batch by scenario tag (in
        # submission order within each group) and run each group through
        # its own program — the (scenario, shard) bucketing of the plane
        tags = np.asarray(batch[_SCENARIO_COL])
        results: Dict[str, Dict[str, np.ndarray]] = {}
        for s in self.scenarios:
            m = valid & (tags == s)
            if not m.any():
                continue
            rows_s = {
                c: np.asarray(v)[m]
                for c, v in batch.items()
                if c not in ("__valid__", _SCENARIO_COL)
            }
            out = self.service.request(rows_s, ingest=self.ingest, scenario=s)
            # rows_s was masked by `m`, so every row is a real request
            self._count_shards(rows_s[key_col], None, s)
            results[s] = {k: np.asarray(v) for k, v in out.items()}
        return results

    def drain(
        self, now_us: Optional[int] = None
    ) -> Optional[Dict[str, np.ndarray]]:
        """Flush everything queued; concatenated rows in submission order
        (per scenario, for a multi-scenario service)."""
        outs: List[Dict] = []
        while True:
            got = self.pump(now_us=now_us, flush=True)
            if got is None:
                break
            outs.append(got)
        if not outs:
            return None
        if self.scenarios is None:
            return {
                k: np.concatenate([o[k] for o in outs]) for k in outs[0]
            }
        merged: Dict[str, Dict[str, np.ndarray]] = {}
        for o in outs:
            for s, cols in o.items():
                if s not in merged:
                    merged[s] = {k: [v] for k, v in cols.items()}
                else:
                    for k, v in cols.items():
                        merged[s][k].append(v)
        return {
            s: {k: np.concatenate(vs) for k, vs in cols.items()}
            for s, cols in merged.items()
        }

    def shard_histogram(self) -> np.ndarray:
        """Requests served per shard, summed over scenarios (copy).

        Counts real requests only — padded filler rows are excluded (see
        :meth:`_count_shards`); padding cost is the
        ``padding_rows_total``/``padding_waste_ratio`` telemetry.
        """
        return self.shard_requests.copy()

    def scenario_shard_histogram(self) -> Dict[str, np.ndarray]:
        """Per-(scenario, shard) request occupancy (copies); real requests
        only, padding excluded as in :meth:`shard_histogram`."""
        return {
            s: h.copy() for s, h in self.scenario_shard_requests.items()
        }
