"""Sharded serving front-end — routing + micro-batching over shard meshes.

The serving plane's request dataflow (FeatInsight's online engine, scaled
out the way OpenMLDB partitions online table state across nodes):

    submit(row) ──> BatchScheduler          (coalesce: max_batch / max_wait_us)
        │
        ▼ next_batch()  — padded shape bucket + __valid__ mask
    FeatureService.request / request_mixed
        │
        ▼ ShardedOnlineStore.query          (one fused program on the mesh)
        │     device (default, ``device_routing=True``): shard =
        │     feistel(key) % S, rank-within-shard (Pallas route kernel on
        │     TPU), scatter into per-shard grids, vmapped per-shard query,
        │     gather back to request order — ALL inside one jit program;
        │     the host sees one dispatch and one transfer per batch.
        │     host (``device_routing=False`` oracle): bucket rows by shard
        │     on the host, pad per shard, device_put with
        │     NamedSharding('shard'), query, scatter back on the host.
        ▼
    per-request feature rows (submission order)

:class:`ShardRouter` owns that loop and the serving-side observability:
per-shard request occupancy (skew monitoring) and the service's latency
percentiles.  The histograms are fed by the store's own routing counts
(``route_info``) — the router never re-hashes keys.  It is
store-agnostic — a single-device store degrades to S=1 — so services opt
into sharding purely via ``FeatureService.build(..., sharded=True)``.

**Multi-scenario routing** (``FeatureService.build_multi``): requests are
submitted with a scenario tag and coalesce in ONE queue.  With device
routing the whole mixed batch goes through
:meth:`~repro.serve.service.MultiScenarioService.request_mixed` — ONE
fused dispatch answers every (scenario, shard) bucket, and per-scenario
rows come back in submission order.  With the host oracle each popped
batch is partitioned by scenario on the host and every group runs its own
program (the legacy per-group path, bit-identical).  Occupancy is tracked
per (scenario, shard) in :meth:`ShardRouter.scenario_shard_histogram`
under both flavours.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs import get_telemetry
from repro.serve.service import (
    SCENARIO_COL,
    BatchScheduler,
    FeatureService,
    MultiScenarioService,
)

__all__ = ["ShardRouter"]

_SCENARIO_COL = SCENARIO_COL


class ShardRouter:
    """Micro-batching front-end for a (sharded, multi-scenario) service.

    ``pump()`` moves one batch through the pipeline; ``drain()`` pumps
    until the queue is empty (flushing any open coalescing window).
    Responses come back as per-request feature rows in submission order —
    for a multi-scenario service, per scenario:
    ``{scenario: {feature: rows-in-submission-order}}``.
    """

    def __init__(
        self,
        service: FeatureService,
        scheduler: Optional[BatchScheduler] = None,
        ingest: bool = True,
    ):
        self.service = service
        self.scheduler = scheduler if scheduler is not None else BatchScheduler()
        self.ingest = ingest
        self.num_shards = int(getattr(service.store, "num_shards", 1))
        self.scenarios: Optional[List[str]] = (
            list(service.scenarios)
            if isinstance(service, MultiScenarioService)
            else None
        )
        # per-shard request counts — the serving-skew histogram (aggregate
        # over scenarios), plus the per-(scenario, shard) breakdown for
        # multi-scenario deployments
        self.shard_requests = np.zeros(self.num_shards, np.int64)
        self.scenario_shard_requests: Dict[str, np.ndarray] = {
            s: np.zeros(self.num_shards, np.int64)
            for s in (self.scenarios or ())
        }

    def _sync_scenarios(self) -> None:
        """Pick up scenarios hot-deployed onto the service since this
        router was built (``MultiScenarioService.hot_deploy``): the
        scenario list and its per-(scenario, shard) histograms follow the
        live plane."""
        if self.scenarios is None:
            return
        live = list(self.service.scenarios)
        if live != self.scenarios:
            self.scenarios = live
            for s in live:
                self.scenario_shard_requests.setdefault(
                    s, np.zeros(self.num_shards, np.int64)
                )

    def submit(
        self,
        row: Dict,
        now_us: Optional[int] = None,
        scenario: Optional[str] = None,
    ) -> None:
        """Queue one request row; multi-scenario services require the
        ``scenario`` tag (which view answers this row)."""
        self._sync_scenarios()
        if self.scenarios is not None:
            if scenario is None:
                raise ValueError(
                    "multi-scenario router: submit(..., scenario=) required "
                    f"(one of {self.scenarios})"
                )
            if scenario not in self.scenario_shard_requests:
                raise KeyError(
                    f"unknown scenario {scenario!r}; service has "
                    f"{self.scenarios}"
                )
            row = dict(row)
            row[_SCENARIO_COL] = scenario
        elif scenario is not None:
            raise ValueError(
                f"service {self.service.name!r} is single-scenario; "
                "submit() takes no scenario tag"
            )
        self.scheduler.submit(row, now_us=now_us)

    def _note_route(
        self, counts: np.ndarray, scenario: Optional[str]
    ) -> None:
        """Fold one batch's routed-row counts into the skew histograms.

        ``counts`` is the per-shard histogram the store computed WHILE
        routing (``route_info["shard_counts"]`` /
        ``["scenario_shard_counts"]``), so the router never re-hashes keys
        to learn where rows went.  Padding is already excluded: the store
        masks filler rows before counting, so the histograms count real
        requests only and the plane's padding cost stays in the
        ``padding_rows_total`` / ``padding_waste_ratio`` telemetry.  The
        per-(scenario, shard) dispatch counter is one vectorized
        ``inc_along`` update, not a per-shard ``inc`` loop.
        """
        hist = np.zeros(self.num_shards, np.int64)
        counts = np.asarray(counts, np.int64)
        hist[: len(counts)] += counts
        self.shard_requests += hist
        if scenario is not None:
            self.scenario_shard_requests[scenario] += hist
        get_telemetry().metrics.counter(
            "shard_dispatch_rows_total",
            "request rows dispatched per (scenario, shard)", "1",
            labels=("scenario", "shard"),
            max_series=1024,
        ).inc_along(
            "shard",
            [str(i) for i in range(self.num_shards)],
            hist,
            scenario=scenario or "",
        )

    def pump(
        self, now_us: Optional[int] = None, flush: bool = False
    ) -> Optional[Dict[str, np.ndarray]]:
        """Serve one coalesced batch; None if nothing is ready yet."""
        self._sync_scenarios()
        batch = self.scheduler.next_batch(now_us=now_us, flush=flush)
        if batch is None:
            return None
        valid = np.asarray(batch["__valid__"], bool)
        get_telemetry().metrics.gauge(
            "batch_occupancy_ratio",
            "real rows / padded batch rows, last batch", "1",
            labels=("service",),
        ).set(
            float(valid.sum()) / max(len(valid), 1),
            service=self.service.name,
        )
        if self.scenarios is None:
            ri: Dict = {}
            out = self.service.request(
                batch, ingest=self.ingest, route_info=ri
            )
            self._note_route(ri["shard_counts"], None)
            return {k: np.asarray(v)[valid] for k, v in out.items()}
        if getattr(self.service.store, "device_routing", False):
            # device routing: the mixed batch is ONE fused dispatch — the
            # store routes, answers, and histograms every (scenario,
            # shard) bucket inside a single jit program
            ri = {}
            results = self.service.request_mixed(
                batch, ingest=self.ingest, route_info=ri
            )
            scounts = np.asarray(ri["scenario_shard_counts"])
            for i, s in enumerate(ri["scenario_names"]):
                self._note_route(scounts[i], s)
            return {
                s: {k: np.asarray(v) for k, v in cols.items()}
                for s, cols in results.items()
            }
        # host oracle: partition the popped batch by scenario tag (in
        # submission order within each group) and run each group through
        # its own program — the (scenario, shard) bucketing of the plane.
        # Ingest is deferred until EVERY group is answered so the whole
        # batch is served as-of batch start, exactly the point-in-time
        # semantics the fused dispatch has (one program cannot interleave
        # per-group ingest into its own answers) — without the deferral
        # a later group would see an earlier group's rows from the same
        # batch and the two flavours could not be bit-identical.
        tags = np.asarray(batch[_SCENARIO_COL])
        results = {}
        groups = []
        for s in self.scenarios:
            m = valid & (tags == s)
            if not m.any():
                continue
            rows_s = {
                c: np.asarray(v)[m]
                for c, v in batch.items()
                if c not in ("__valid__", _SCENARIO_COL)
            }
            ri = {}
            out = self.service.request(
                rows_s, ingest=False, scenario=s, route_info=ri
            )
            # rows_s was masked by `m`, so every row is a real request
            self._note_route(ri["shard_counts"], s)
            results[s] = {k: np.asarray(v) for k, v in out.items()}
            groups.append(rows_s)
        if self.ingest:
            schema = self.service.view.schema
            for rows_s in groups:
                data = {
                    c: np.asarray(v)
                    for c, v in rows_s.items()
                    if not c.startswith("__")
                }
                order = np.lexsort((data[schema.ts], data[schema.key]))
                self.service.store.ingest(
                    {c: v[order] for c, v in data.items()}
                )
        return results

    def drain(
        self, now_us: Optional[int] = None
    ) -> Optional[Dict[str, np.ndarray]]:
        """Flush everything queued; concatenated rows in submission order
        (per scenario, for a multi-scenario service)."""
        outs: List[Dict] = []
        while True:
            got = self.pump(now_us=now_us, flush=True)
            if got is None:
                break
            outs.append(got)
        if not outs:
            return None
        if self.scenarios is None:
            return {
                k: np.concatenate([o[k] for o in outs]) for k in outs[0]
            }
        # collect every pump's per-scenario chunks first, concatenate each
        # scenario ONCE at the end — pumps arrive in submission order, so
        # chunk order is row order and a single concat per (scenario,
        # feature) preserves it without O(pumps) repeated reallocation
        merged: Dict[str, Dict[str, List[np.ndarray]]] = {}
        for o in outs:
            for s, cols in o.items():
                dst = merged.setdefault(s, {})
                for k, v in cols.items():
                    dst.setdefault(k, []).append(v)
        return {
            s: {k: np.concatenate(vs) for k, vs in cols.items()}
            for s, cols in merged.items()
        }

    def shard_histogram(self) -> np.ndarray:
        """Requests served per shard, summed over scenarios (copy).

        Counts real requests only — padded filler rows are excluded (see
        :meth:`_count_shards`); padding cost is the
        ``padding_rows_total``/``padding_waste_ratio`` telemetry.
        """
        return self.shard_requests.copy()

    def scenario_shard_histogram(self) -> Dict[str, np.ndarray]:
        """Per-(scenario, shard) request occupancy (copies); real requests
        only, padding excluded as in :meth:`shard_histogram`."""
        return {
            s: h.copy() for s, h in self.scenario_shard_requests.items()
        }
