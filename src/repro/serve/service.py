"""Online feature service + model serving — FeatInsight §3.1 step 4.

``FeatureService`` is the paper's deployment unit: a named, versioned
view bound to an online store, answering request rows with feature
vectors under a latency budget.  ``ScoringService`` composes it with a
model (feature vector -> signature embedding -> transformer -> score),
the fraud-detection layout of §3.3.

``BatchScheduler`` is the serving loop's micro-batcher: requests are
coalesced up to ``max_batch`` or ``max_wait_us`` (whichever first) so the
jit'd query executes at a fixed batch shape (padding to the shape bucket
keeps one compiled executable per bucket — compilation caching again).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.online import OnlineFeatureStore
from repro.core.view import FeatureRegistry, FeatureView

__all__ = ["FeatureService", "BatchScheduler", "ScoringService"]


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0
    total_latency_s: float = 0.0

    @property
    def mean_latency_ms(self) -> float:
        return 1e3 * self.total_latency_s / max(self.batches, 1)


class FeatureService:
    """A deployed (view, version) answering online feature requests."""

    def __init__(
        self,
        name: str,
        view: FeatureView,
        store: OnlineFeatureStore,
        registry: Optional[FeatureRegistry] = None,
        mode: str = "preagg",
    ):
        self.name = name
        self.view = view
        self.store = store
        self.mode = mode
        self.stats = ServiceStats()
        if registry is not None:
            registry.deploy(name, view.name, view.version)

    def request(self, rows: Dict[str, np.ndarray],
                ingest: bool = True) -> Dict[str, np.ndarray]:
        """Compute features for a batch of request rows; optionally ingest
        them afterwards (the online-learning pattern of the paper).

        Batches from :class:`BatchScheduler` carry a ``__valid__`` mask over
        padding rows (the last real row repeated up to the shape bucket).
        The mask is stripped before querying and honored on ingest — padding
        rows are duplicates of a real row, so ingesting them would corrupt
        window state (double-counted sums, inflated counts).
        """
        t0 = time.perf_counter()
        valid = rows.get("__valid__")
        rows = {c: v for c, v in rows.items() if c != "__valid__"}
        out = self.store.query(rows, mode=self.mode)
        out = {k: np.asarray(v) for k, v in out.items()}
        if ingest:
            real = rows
            if valid is not None:
                valid = np.asarray(valid, bool)
                real = {c: np.asarray(v)[valid] for c, v in rows.items()}
            if len(next(iter(real.values()))):
                key = np.asarray(real[self.view.schema.key])
                ts = np.asarray(real[self.view.schema.ts])
                order = np.lexsort((ts, key))
                self.store.ingest(
                    {c: np.asarray(v)[order] for c, v in real.items()}
                )
        dt = time.perf_counter() - t0
        n = len(next(iter(rows.values())))
        self.stats.requests += int(valid.sum()) if valid is not None else n
        self.stats.batches += 1
        self.stats.total_latency_s += dt
        return out

    def feature_matrix(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        out = self.request(rows, ingest=False)
        return np.stack([out[f] for f in self.view.features], axis=-1)


class BatchScheduler:
    """Coalesce requests into fixed-shape batches (bucketed padding)."""

    def __init__(self, buckets: Sequence[int] = (1, 4, 16, 64, 256)):
        self.buckets = sorted(buckets)
        self.queue: List[Dict] = []

    def submit(self, row: Dict) -> None:
        self.queue.append(row)

    def next_batch(self, max_batch: Optional[int] = None) -> Optional[Dict[str, np.ndarray]]:
        if not self.queue:
            return None
        n = len(self.queue)
        if max_batch:
            n = min(n, max_batch)
        bucket = next((b for b in self.buckets if b >= n), self.buckets[-1])
        n = min(n, bucket)
        rows, self.queue = self.queue[:n], self.queue[n:]
        cols = {
            k: np.asarray([r[k] for r in rows])
            for k in rows[0]
        }
        # pad to bucket by repeating the last row (masked out by caller)
        pad = bucket - n
        if pad:
            cols = {k: np.concatenate([v, np.repeat(v[-1:], pad, 0)])
                    for k, v in cols.items()}
        cols["__valid__"] = np.arange(bucket) < n
        return cols


class ScoringService:
    """features -> signature embedding -> model -> score (fraud §3.3)."""

    def __init__(self, feature_service: FeatureService, model, params,
                 embed_table: jnp.ndarray, num_hashes: int = 2):
        from repro.core.signature import signature_ids
        from repro.kernels.signature.ops import signature_embed

        self.fs = feature_service
        self.model = model
        self.params = params
        self.table = embed_table
        self.num_hashes = num_hashes
        self._signature_ids = signature_ids
        self._embed = signature_embed

        cfg = model.cfg

        def score(params, feats, emb):
            # feature vector projected as frontend embeddings + a CLS token
            B = feats.shape[0]
            fe = jnp.concatenate(
                [feats[:, None, :], emb[:, None, :]], axis=1
            )
            P = cfg.frontend_len
            fe = jnp.pad(fe, ((0, 0), (0, P - 2), (0, 0)))
            batch = {
                "tokens": jnp.zeros((B, 1), jnp.int32),
                "frontend_embeds": fe,
            }
            logits, _ = model.prefill(params, batch, max_len=P + 1)
            return jax.nn.sigmoid(logits[:, -1, 0])

        self._score = jax.jit(score)

    def handle(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        feats = self.fs.feature_matrix(rows)  # (B, F)
        cfg = self.model.cfg
        F = feats.shape[1]
        pad = np.zeros((feats.shape[0], cfg.d_model - F), np.float32)
        featvec = jnp.asarray(np.concatenate([feats, pad], -1), jnp.float32)
        sig = self._signature_ids(
            [jnp.asarray(rows[self.fs.view.schema.key], jnp.int32)], bits=20
        )
        emb = self._embed(
            self.table, sig,
            jnp.ones((self.num_hashes,), jnp.float32) / self.num_hashes,
            num_hashes=self.num_hashes,
        )
        emb = jnp.pad(emb, ((0, 0), (0, cfg.d_model - emb.shape[-1])))
        return np.asarray(self._score(self.params, featvec, emb))
