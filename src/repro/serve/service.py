"""Online feature service + model serving — FeatInsight §3.1 step 4.

``FeatureService`` is the paper's deployment unit: a named, versioned
view bound to an online store, answering request rows with feature
vectors under a latency budget.  ``ScoringService`` composes it with a
model (feature vector -> signature embedding -> transformer -> score),
the fraud-detection layout of §3.3.

``BatchScheduler`` is the serving loop's micro-batcher: requests are
coalesced up to ``max_batch`` or ``max_wait_us`` (whichever first) so the
jit'd query executes at a fixed batch shape (padding to the shape bucket
keeps one compiled executable per bucket — compilation caching again).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.online import OnlineFeatureStore
from repro.core.view import FeatureRegistry, FeatureView
from repro.obs import get_telemetry

__all__ = [
    "FeatureService",
    "MultiScenarioService",
    "BatchScheduler",
    "ScoringService",
    "SCENARIO_COL",
]

# meta column carrying the per-row scenario tag of a mixed batch (set by
# ShardRouter.submit, consumed by MultiScenarioService.request_mixed)
SCENARIO_COL = "__scenario__"


@dataclasses.dataclass
class ServiceStats:
    """Request counters + latency distributions.

    The paper's latency claims are *tail*-latency claims (<20 ms at
    QPS > 1000), so the stats keep rings of recent samples and report
    percentiles, not just the mean.

    Two distributions live here:

    * **per-request** (``request_p50_ms`` / ``request_p95_ms`` /
      ``request_p99_ms``): one sample per request — queue wait plus the
      wall time of the batch that served it — so a 64-request batch
      contributes 64 samples and the tail reflects what a user request
      actually experienced.  This is the authoritative latency metric.
    * **per-batch** (``p50_ms`` / ``p95_ms`` / ``p99_ms``): one sample per
      batch wall time, *unweighted* by batch size.  Deprecated — kept
      working for existing dashboards/tests, but it under-weights busy
      batches (a 1-row batch counts the same as a 256-row one) and
      excludes queue wait.  New code should read the request percentiles.
    """

    requests: int = 0
    batches: int = 0
    total_latency_s: float = 0.0
    window: int = 1024
    recent_latency_s: List[float] = dataclasses.field(
        default_factory=list, repr=False
    )
    recent_request_latency_s: List[float] = dataclasses.field(
        default_factory=list, repr=False
    )

    def observe(self, latency_s: float, n_requests: int) -> None:
        """Record one served batch (batch wall time + request count).

        Without per-request wait attribution, each of the batch's
        requests is also credited the batch wall time in the per-request
        ring; :meth:`observe_requests` overrides that with true
        wait-inclusive samples when the caller has them.
        """
        self.requests += n_requests
        self.batches += 1
        self.total_latency_s += latency_s
        self.recent_latency_s.append(latency_s)
        if len(self.recent_latency_s) > self.window:
            del self.recent_latency_s[: len(self.recent_latency_s) - self.window]

    def observe_requests(self, latencies_s: Sequence[float]) -> None:
        """Record per-request end-to-end latencies (wait + batch wall)."""
        self.recent_request_latency_s.extend(float(x) for x in latencies_s)
        if len(self.recent_request_latency_s) > self.window:
            del self.recent_request_latency_s[
                : len(self.recent_request_latency_s) - self.window
            ]

    @property
    def mean_latency_ms(self) -> float:
        return 1e3 * self.total_latency_s / max(self.batches, 1)

    def percentile_ms(self, p: float) -> float:
        """DEPRECATED batch-latency percentile (unweighted by batch size)."""
        if not self.recent_latency_s:
            return 0.0
        return 1e3 * float(np.percentile(np.asarray(self.recent_latency_s), p))

    def request_percentile_ms(self, p: float) -> float:
        """Per-request latency percentile (queue wait + batch wall time)."""
        if not self.recent_request_latency_s:
            return 0.0
        return 1e3 * float(
            np.percentile(np.asarray(self.recent_request_latency_s), p)
        )

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50.0)

    @property
    def p95_ms(self) -> float:
        return self.percentile_ms(95.0)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99.0)

    @property
    def request_p50_ms(self) -> float:
        return self.request_percentile_ms(50.0)

    @property
    def request_p95_ms(self) -> float:
        return self.request_percentile_ms(95.0)

    @property
    def request_p99_ms(self) -> float:
        return self.request_percentile_ms(99.0)


class FeatureService:
    """A deployed (view, version) answering online feature requests."""

    def __init__(
        self,
        name: str,
        view: FeatureView,
        store: OnlineFeatureStore,
        registry: Optional[FeatureRegistry] = None,
        mode: str = "preagg",
    ):
        self.name = name
        self.view = view
        self.store = store
        self.mode = mode
        self.registry = registry
        self.stats = ServiceStats()
        if registry is not None:
            registry.deploy(name, view.name, view.version)

    @classmethod
    def build(
        cls,
        name: str,
        view: FeatureView,
        *,
        num_keys: int,
        registry: Optional[FeatureRegistry] = None,
        mode: str = "preagg",
        sharded: bool = False,
        num_shards: Optional[int] = None,
        **store_kwargs,
    ) -> "FeatureService":
        """Construct the service together with its online store.

        ``sharded=True`` deploys on a :class:`~repro.core.shard.
        ShardedOnlineStore` — view state key-partitioned across
        ``num_shards`` shards (default: one per local device) on a device
        mesh, answers bit-identical to the single-device store.  The
        request path is unchanged; compose with :class:`ScoringService`
        and :class:`~repro.serve.router.ShardRouter` as usual.
        """
        if not sharded and num_shards is not None:
            raise ValueError("num_shards requires sharded=True")
        if sharded and num_shards is None:
            num_shards = max(len(jax.devices()), 1)
        store = OnlineFeatureStore.create(
            view, num_keys=num_keys, num_shards=num_shards, **store_kwargs
        )
        return cls(name, view, store, registry=registry, mode=mode)

    @classmethod
    def build_multi(
        cls,
        name: str,
        views: Sequence[FeatureView],
        *,
        num_keys: int,
        registry: Optional[FeatureRegistry] = None,
        mode: str = "preagg",
        sharded: bool = False,
        num_shards: Optional[int] = None,
        **store_kwargs,
    ) -> "MultiScenarioService":
        """Deploy N scenario views as ONE service on ONE shared store.

        The views are fused into a :class:`~repro.core.scenario.
        ScenarioPlane`: shared tables are ingested and stored once (per
        shard, with ``sharded=True`` — all scenarios live on a single
        ``('shard',)`` mesh), and each view queries through its own
        compiled program, bit-identical to a dedicated single-view store.
        Requests carry a ``scenario=`` tag:
        ``svc.request(rows, scenario="fraud")``; per-scenario latency/QPS
        lands in ``svc.scenario_stats[...]`` alongside the aggregate
        ``svc.stats``.
        """
        from repro.core.scenario import ScenarioPlane

        if not sharded and num_shards is not None:
            raise ValueError("num_shards requires sharded=True")
        if sharded and num_shards is None:
            num_shards = max(len(jax.devices()), 1)
        plane = ScenarioPlane(
            views,
            num_keys=num_keys,
            num_shards=num_shards,
            name=name,
            **store_kwargs,
        )
        return MultiScenarioService(name, plane, registry=registry, mode=mode)

    # -- per-request hooks (MultiScenarioService overrides both) -------------

    def _compute(
        self,
        rows: Dict[str, np.ndarray],
        scenario: Optional[str],
        valid: Optional[np.ndarray] = None,
        route_info: Optional[Dict] = None,
    ) -> Dict[str, np.ndarray]:
        if scenario is not None:
            raise ValueError(
                f"service {self.name!r} is single-scenario; scenario= tags "
                "need a FeatureService.build_multi deployment"
            )
        return self.store.query(
            rows, mode=self.mode, valid=valid, route_info=route_info
        )

    def _observe(
        self,
        latency_s: float,
        n_requests: int,
        scenario: Optional[str],
        request_latencies_s: Optional[np.ndarray] = None,
    ) -> None:
        self.stats.observe(latency_s, n_requests)
        if request_latencies_s is not None:
            self.stats.observe_requests(request_latencies_s)

    def request(self, rows: Dict[str, np.ndarray],
                ingest: bool = True,
                scenario: Optional[str] = None,
                route_info: Optional[Dict] = None) -> Dict[str, np.ndarray]:
        """Compute features for a batch of request rows; optionally ingest
        them afterwards (the online-learning pattern of the paper).

        Batches from :class:`BatchScheduler` carry a ``__valid__`` mask over
        padding rows (the last real row repeated up to the shape bucket)
        and a ``__wait_us__`` per-row queue-wait column.  All ``__``-meta
        columns are stripped before querying; the mask is honored on ingest
        — padding rows are duplicates of a real row, so ingesting them
        would corrupt window state (double-counted sums, inflated counts).
        The wait column attributes per-request latency: each request's
        sample is its queue wait plus this batch's wall time.

        ``scenario`` selects which view answers on a multi-scenario
        deployment (see :meth:`build_multi`); ingested rows land in the
        shared store once, serving every scenario.  ``route_info`` (dict,
        filled in place) surfaces the store's per-shard routing counts to
        the caller — the router's skew histograms read them instead of
        re-hashing keys.
        """
        tel = get_telemetry()
        t0 = tel.clock.now()
        valid = rows.get("__valid__")
        wait_us = rows.get("__wait_us__")
        rows = {c: v for c, v in rows.items() if not c.startswith("__")}
        n_rows = len(next(iter(rows.values())))
        n_real = int(np.asarray(valid, bool).sum()) if valid is not None else n_rows
        with tel.tracer.span(
            "request", service=self.name,
            scenario=scenario or "", rows=n_real,
        ):
            out = self._compute(
                rows, scenario, valid=valid, route_info=route_info
            )
            out = {k: np.asarray(v) for k, v in out.items()}
            if ingest:
                real = rows
                if valid is not None:
                    valid = np.asarray(valid, bool)
                    real = {c: np.asarray(v)[valid] for c, v in rows.items()}
                if len(next(iter(real.values()))):
                    key = np.asarray(real[self.view.schema.key])
                    ts = np.asarray(real[self.view.schema.ts])
                    order = np.lexsort((ts, key))
                    self.store.ingest(
                        {c: np.asarray(v)[order] for c, v in real.items()}
                    )
        dt = tel.clock.now() - t0
        # per-request latency = that request's queue wait + batch wall time
        if wait_us is not None:
            waits_s = np.asarray(wait_us, np.float64)[:n_rows] / 1e6
            if valid is not None:
                waits_s = waits_s[np.asarray(valid, bool)]
            else:
                waits_s = waits_s[:n_real]
        else:
            waits_s = np.zeros(n_real, np.float64)
        req_lat = waits_s + dt
        m = tel.metrics
        m.counter(
            "service_requests_total", "requests served", "1",
            labels=("service", "scenario"),
        ).inc(n_real, service=self.name, scenario=scenario or "")
        m.histogram(
            "request_latency_seconds",
            "per-request latency (queue wait + batch wall)", "s",
            labels=("service",),
        ).observe_array(req_lat, service=self.name)
        if wait_us is not None and len(waits_s):
            m.histogram(
                "queue_wait_seconds", "scheduler queue wait per request",
                "s", labels=("service",),
            ).observe_array(waits_s, service=self.name)
        if valid is not None and n_rows:
            m.gauge(
                "batch_occupancy_ratio",
                "real rows / padded batch rows, last batch", "1",
                labels=("service",),
            ).set(n_real / n_rows, service=self.name)
        self._observe(dt, n_real, scenario, req_lat)
        return out

    def feature_matrix(
        self, rows: Dict[str, np.ndarray], scenario: Optional[str] = None
    ) -> np.ndarray:
        out = self.request(rows, ingest=False, scenario=scenario)
        feats = self._scenario_features(scenario)
        return np.stack([out[f] for f in feats], axis=-1)

    def _scenario_features(self, scenario: Optional[str]) -> Sequence[str]:
        return self.view.features


class MultiScenarioService(FeatureService):
    """One deployment serving N scenarios from one shared store and mesh.

    ``view``/``store`` are the plane's merged view and shared store, so
    everything written against :class:`FeatureService` (routers, stats
    consumers, ingest paths) keeps working; queries additionally take the
    ``scenario=`` tag and answer with that view's features by their
    original (un-prefixed) names.  Deploy records land in the registry as
    ``"<service>:<scenario>"`` per scenario.
    """

    def __init__(
        self,
        name: str,
        plane,  # repro.core.scenario.ScenarioPlane
        registry: Optional[FeatureRegistry] = None,
        mode: str = "preagg",
    ):
        self.plane = plane
        super().__init__(name, plane.merged, plane.store, mode=mode)
        self.registry = registry
        self.scenario_stats: Dict[str, ServiceStats] = {
            s: ServiceStats() for s in plane.scenarios
        }
        if registry is not None:
            for s, v in plane.views.items():
                registry.deploy(f"{name}:{s}", v.name, v.version)

    @property
    def scenarios(self) -> List[str]:
        return self.plane.scenarios

    def hot_deploy(self, view: FeatureView, backfill=None, **plan_overrides):
        """Deploy one more scenario onto the LIVE plane — no rebuild, no
        re-ingest, no downtime for the scenarios already serving.

        Drives :meth:`~repro.core.scenario.ScenarioPlane.evolve`: the
        layout planner re-plans for ``views + [view]``, the running
        store's state migrates to the new plan (carried buffers verbatim,
        new lanes synthesized from history), and only the new view's
        :class:`~repro.core.online.QueryProgram` is compiled.  The
        deployment is recorded in the registry as
        ``"<service>:<scenario>"`` with a ``hot deploy`` description
        (the view is registered first if the registry does not know it),
        and a fresh per-scenario :class:`ServiceStats` starts counting.

        ``backfill`` (a :class:`repro.offline.backfill.BackfillSource`)
        lets the deployment reach beyond the rings' retention horizon:
        aged-out state the migration cannot reconstruct is re-derived
        from offline history and spliced in, keeping ``report.exact``.

        Returns the :class:`~repro.core.migrate.MigrationReport`.
        """
        if view.name in self.plane.views:
            raise ValueError(
                f"scenario {view.name!r} is already deployed on "
                f"{self.name!r}; hot_deploy adds new scenarios"
            )
        tel = get_telemetry()
        with tel.tracer.span(
            "hot_deploy", service=self.name, scenario=view.name
        ):
            report = self.plane.evolve(
                list(self.plane.views.values()) + [view],
                backfill=backfill, **plan_overrides,
            )
        tel.metrics.counter(
            "hot_deploys_total", "scenarios hot-deployed onto live planes",
            "1", labels=("service",),
        ).inc(service=self.name)
        self.view = self.plane.merged
        self.scenario_stats.setdefault(view.name, ServiceStats())
        if self.registry is not None:
            try:
                self.registry.get(view.name, view.version)
            except KeyError:
                self.registry.register(view)
            self.registry.deploy(
                f"{self.name}:{view.name}",
                view.name,
                view.version,
                description="hot deploy (live plane evolution)",
            )
        return report

    def _compute(self, rows, scenario, valid=None, route_info=None):
        if scenario is None:
            raise ValueError(
                f"multi-scenario service {self.name!r} needs scenario= "
                f"(one of {self.scenarios})"
            )
        return self.plane.query(
            scenario, rows, mode=self.mode, valid=valid, route_info=route_info
        )

    def request_mixed(
        self,
        rows: Dict[str, np.ndarray],
        ingest: bool = True,
        route_info: Optional[Dict] = None,
    ) -> Dict[str, Dict[str, np.ndarray]]:
        """Serve one mixed multi-scenario batch with ONE fused dispatch.

        The batch carries a per-row ``__scenario__`` tag
        (:data:`SCENARIO_COL`, set by ``ShardRouter.submit``) alongside the
        usual ``__valid__`` / ``__wait_us__`` meta columns.  Instead of
        partitioning by scenario on the host and running one store query
        per group, the whole batch enters :meth:`~repro.core.scenario.
        ScenarioPlane.query_mixed` — one fused on-device route+query
        program for all scenarios and shards — and the answer comes back
        as ``{scenario: {feature: rows}}`` with each scenario's rows in
        submission order, bit-identical to the per-group path.

        Ingest preserves the legacy stream semantics exactly: real rows
        are grouped by scenario (scenario order), each group sorted by
        (key, ts), and ingested group-by-group — the same order the
        per-group path produced.  Stats/metrics are recorded per scenario
        (each request's latency sample is its queue wait plus this fused
        batch's wall time) plus the aggregate, and ``batches`` counts ONE
        batch, reflecting the single dispatch.
        """
        if SCENARIO_COL not in rows:
            raise ValueError(
                f"request_mixed needs a {SCENARIO_COL!r} tag column "
                "(per-row scenario names; ShardRouter.submit sets it)"
            )
        tel = get_telemetry()
        t0 = tel.clock.now()
        tags = np.asarray(rows[SCENARIO_COL])
        valid = rows.get("__valid__")
        wait_us = rows.get("__wait_us__")
        data = {c: v for c, v in rows.items() if not c.startswith("__")}
        n_rows = len(next(iter(data.values())))
        vmask = (
            np.asarray(valid, bool)[:n_rows]
            if valid is not None
            else np.ones(n_rows, bool)
        )
        n_real = int(vmask.sum())
        with tel.tracer.span(
            "request", service=self.name, scenario="mixed", rows=n_real
        ):
            out = self.plane.query_mixed(
                data, tags, mode=self.mode, valid=vmask,
                route_info=route_info,
            )
            if ingest and n_real:
                key_c = self.view.schema.key
                ts_c = self.view.schema.ts
                for s in self.scenarios:
                    m = vmask & (tags == s)
                    if not m.any():
                        continue
                    grp = {c: np.asarray(v)[m] for c, v in data.items()}
                    order = np.lexsort(
                        (np.asarray(grp[ts_c]), np.asarray(grp[key_c]))
                    )
                    self.store.ingest({c: v[order] for c, v in grp.items()})
        dt = tel.clock.now() - t0
        if wait_us is not None:
            waits_s = np.asarray(wait_us, np.float64)[:n_rows] / 1e6
        else:
            waits_s = np.zeros(n_rows, np.float64)
        agg_waits = waits_s[vmask]
        req_lat = agg_waits + dt
        m = tel.metrics
        sreq = m.counter(
            "service_requests_total", "requests served", "1",
            labels=("service", "scenario"),
        )
        m.histogram(
            "request_latency_seconds",
            "per-request latency (queue wait + batch wall)", "s",
            labels=("service",),
        ).observe_array(req_lat, service=self.name)
        if wait_us is not None and len(agg_waits):
            m.histogram(
                "queue_wait_seconds", "scheduler queue wait per request",
                "s", labels=("service",),
            ).observe_array(agg_waits, service=self.name)
        if valid is not None and n_rows:
            m.gauge(
                "batch_occupancy_ratio",
                "real rows / padded batch rows, last batch", "1",
                labels=("service",),
            ).set(n_real / n_rows, service=self.name)
        self.stats.observe(dt, n_real)
        self.stats.observe_requests(req_lat)
        for s in self.scenarios:
            msk = vmask & (tags == s)
            n_s = int(msk.sum())
            if not n_s:
                continue
            sreq.inc(n_s, service=self.name, scenario=s)
            st = self.scenario_stats[s]
            st.observe(dt, n_s)
            st.observe_requests(waits_s[msk] + dt)
        return out

    def _observe(self, latency_s, n_requests, scenario,
                 request_latencies_s=None):
        self.stats.observe(latency_s, n_requests)
        self.scenario_stats[scenario].observe(latency_s, n_requests)
        if request_latencies_s is not None:
            self.stats.observe_requests(request_latencies_s)
            self.scenario_stats[scenario].observe_requests(
                request_latencies_s
            )

    def _scenario_features(self, scenario):
        if scenario is None:
            raise ValueError("feature_matrix needs scenario= on a "
                             "multi-scenario service")
        return self.plane.views[scenario].features


class BatchScheduler:
    """Coalesce requests into fixed-shape batches (bucketed padding).

    With ``max_wait_us`` set, :meth:`next_batch` implements the real
    micro-batching deadline: it holds the queue open until either
    ``max_batch`` requests have accumulated or the *oldest* queued request
    has waited ``max_wait_us`` microseconds — whichever comes first — so a
    trickle of traffic still flushes partial batches within the latency
    budget.  Without it, any queued request flushes immediately (the
    legacy immediate-drain behaviour).

    Time is injectable (``now_us``) so schedulers are testable and
    replayable; real callers omit it and read the plane clock —
    ``repro.obs.get_telemetry().clock`` — so a :class:`repro.obs.FakeClock`
    installed via ``use_telemetry`` drives the scheduler, the registry,
    and every span from the same counter.
    """

    def __init__(
        self,
        buckets: Sequence[int] = (1, 4, 16, 64, 256),
        max_batch: Optional[int] = None,
        max_wait_us: Optional[int] = None,
    ):
        self.buckets = sorted(buckets)
        self.max_batch = max_batch
        self.max_wait_us = max_wait_us
        self.queue: List[Dict] = []
        self._arrival_us: List[int] = []
        self._injected_clock: Optional[bool] = None

    def _clock_us(self, now_us: Optional[int]) -> int:
        # a scheduler must live entirely on one clock: mixing an injected
        # test clock with the plane's monotonic clock would compare epochs
        # microseconds vs ~hours apart and either stall queued requests
        # forever or flush every batch instantly — fail loudly instead
        injected = now_us is not None
        if self._injected_clock is None:
            self._injected_clock = injected
        elif self._injected_clock != injected:
            raise ValueError(
                "BatchScheduler clock mode mixed: pass now_us on every "
                "call or on none (instance started with "
                f"{'injected' if self._injected_clock else 'monotonic'} time)"
            )
        return int(now_us) if injected else get_telemetry().clock.now_us()

    def submit(self, row: Dict, now_us: Optional[int] = None) -> None:
        self.queue.append(row)
        self._arrival_us.append(self._clock_us(now_us))

    def oldest_wait_us(self, now_us: Optional[int] = None) -> Optional[int]:
        if not self._arrival_us:
            return None
        return self._clock_us(now_us) - self._arrival_us[0]

    def next_batch(
        self,
        max_batch: Optional[int] = None,
        now_us: Optional[int] = None,
        flush: bool = False,
    ) -> Optional[Dict[str, np.ndarray]]:
        """Pop the next padded batch, or None.

        None means *empty queue* — or, under a ``max_wait_us`` deadline,
        *keep coalescing*: the queue is neither full (``max_batch``) nor
        expired yet.  ``flush=True`` overrides the deadline (shutdown /
        drain paths).
        """
        if not self.queue:
            return None
        max_batch = max_batch if max_batch is not None else self.max_batch
        if self.max_wait_us is not None and not flush:
            full = max_batch is not None and len(self.queue) >= max_batch
            expired = self.oldest_wait_us(now_us) >= self.max_wait_us
            if not (full or expired):
                return None
        n = len(self.queue)
        if max_batch:
            n = min(n, max_batch)
        bucket = next((b for b in self.buckets if b >= n), self.buckets[-1])
        n = min(n, bucket)
        pop_us = self._clock_us(now_us)
        rows, self.queue = self.queue[:n], self.queue[n:]
        arrivals, self._arrival_us = (
            self._arrival_us[:n], self._arrival_us[n:]
        )
        cols = {
            k: np.asarray([r[k] for r in rows])
            for k in rows[0]
        }
        waits = np.asarray(
            [max(pop_us - a, 0) for a in arrivals], np.int64
        )
        # pad to bucket by repeating the last row (masked out by caller)
        pad = bucket - n
        if pad:
            cols = {k: np.concatenate([v, np.repeat(v[-1:], pad, 0)])
                    for k, v in cols.items()}
            waits = np.concatenate([waits, np.repeat(waits[-1:], pad)])
        cols["__valid__"] = np.arange(bucket) < n
        cols["__wait_us__"] = waits
        m = get_telemetry().metrics
        m.counter(
            "padding_rows_total", "filler rows added to reach shape bucket",
            "1", labels=("layer",),
        ).inc(pad, layer="scheduler")
        m.gauge(
            "padding_waste_ratio", "filler rows / bucket rows, last batch",
            "1", labels=("layer",),
        ).set(pad / bucket, layer="scheduler")
        return cols


class ScoringService:
    """features -> signature embedding -> model -> score (fraud §3.3)."""

    def __init__(self, feature_service: FeatureService, model, params,
                 embed_table: jnp.ndarray, num_hashes: int = 2):
        from repro.core.signature import signature_ids
        from repro.kernels.signature.ops import signature_embed

        self.fs = feature_service
        self.model = model
        self.params = params
        self.table = embed_table
        self.num_hashes = num_hashes
        self._signature_ids = signature_ids
        self._embed = signature_embed

        cfg = model.cfg

        def score(params, feats, emb):
            # feature vector projected as frontend embeddings + a CLS token
            B = feats.shape[0]
            fe = jnp.concatenate(
                [feats[:, None, :], emb[:, None, :]], axis=1
            )
            P = cfg.frontend_len
            fe = jnp.pad(fe, ((0, 0), (0, P - 2), (0, 0)))
            batch = {
                "tokens": jnp.zeros((B, 1), jnp.int32),
                "frontend_embeds": fe,
            }
            logits, _ = model.prefill(params, batch, max_len=P + 1)
            return jax.nn.sigmoid(logits[:, -1, 0])

        self._score = jax.jit(score)

    def handle(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        feats = self.fs.feature_matrix(rows)  # (B, F)
        cfg = self.model.cfg
        F = feats.shape[1]
        pad = np.zeros((feats.shape[0], cfg.d_model - F), np.float32)
        featvec = jnp.asarray(np.concatenate([feats, pad], -1), jnp.float32)
        sig = self._signature_ids(
            [jnp.asarray(rows[self.fs.view.schema.key], jnp.int32)], bits=20
        )
        emb = self._embed(
            self.table, sig,
            jnp.ones((self.num_hashes,), jnp.float32) / self.num_hashes,
            num_hashes=self.num_hashes,
        )
        emb = jnp.pad(emb, ((0, 0), (0, cfg.d_model - emb.shape[-1])))
        return np.asarray(self._score(self.params, featvec, emb))
