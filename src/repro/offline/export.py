"""Point-in-time training-set export from serving feature definitions.

FeatInsight's training path: features for model training are computed
*offline* over historical tables, but from the **same feature view** that
answers online requests — that is what makes the exported training set
consistent with what the model will see in production (the paper's
offline/online consistency pillar, §2(3)).

:func:`export_training_set` is that path for this repo: given a
:class:`~repro.core.view.FeatureView` and its full table history, it runs
the offline engine's fused batch program (:meth:`OfflineEngine.compute` —
point-in-time correct per row: each row's windows see rows at ``ts <=``
its own, LAST JOINs gather the latest secondary row at-or-before it) and
gathers the **label rows** — the rows whose (key, ts) are the training
events.  Label rows are actual history rows, which is exactly the online
replay protocol's request semantics (`verify_view`: query a row against
state including itself, then move on), so the export is verifiable
row-for-row against a live store.

:func:`verify_export` runs that verification: replay the history through
an online store (same rounds/interleaving as
:func:`repro.core.consistency.verify_view`, sharded or not), collect the
online answers at the label rows, and compare against the exported batch
under the same f32 tolerance contract.  ``scripts/ci.sh`` gates on it
(:mod:`repro.offline.check`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.consistency import replay_rounds
from repro.core.engine import OfflineEngine
from repro.core.online import OnlineFeatureStore
from repro.core.view import FeatureView
from repro.obs import get_telemetry

__all__ = [
    "TrainingSet",
    "ExportCheck",
    "sample_label_rows",
    "export_training_set",
    "verify_export",
]


@dataclasses.dataclass
class TrainingSet:
    """One exported, point-in-time-correct training batch.

    ``rows`` indexes the label rows in the source history (input row
    order); ``features`` holds one (L,) f32 column per view feature,
    evaluated as-of each label row's timestamp.
    """

    view: str
    version: int
    rows: np.ndarray                   # (L,) int64 history row indices
    key: np.ndarray                    # (L,) label-row keys
    ts: np.ndarray                     # (L,) label-row timestamps
    features: Dict[str, np.ndarray]    # {feature: (L,) f32}
    label: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return int(self.rows.shape[0])

    def to_columns(self) -> Dict[str, np.ndarray]:
        """Flat columnar batch (features + key/ts + optional label)."""
        out = {"__key__": self.key, "__ts__": self.ts}
        out.update(self.features)
        if self.label is not None:
            out["__label__"] = self.label
        return out

    def describe(self) -> str:
        return (
            f"training set: view={self.view} v{self.version} "
            f"rows={len(self)} features={len(self.features)}"
            f"{' +label' if self.label is not None else ''}"
        )


def sample_label_rows(
    ts: np.ndarray, n: int, seed: int = 0
) -> np.ndarray:
    """Deterministic label-row sampling: ``n`` distinct row indices drawn
    uniformly over the history (seeded, without replacement), returned in
    row order.  Uniform-over-rows means the sample straddles every
    retention horizon the online plane might have — which is the point:
    training labels do not stop where ring capacity does."""
    ts = np.asarray(ts)
    total = int(ts.shape[0])
    n = min(int(n), total)
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(total, size=n, replace=False).astype(np.int64))


def export_training_set(
    view: FeatureView,
    columns: Dict[str, np.ndarray],
    *,
    label_rows: Optional[np.ndarray] = None,
    n: Optional[int] = None,
    seed: int = 0,
    label: Optional[str] = None,
    secondary: Optional[Dict[str, Dict[str, np.ndarray]]] = None,
    engine: Optional[OfflineEngine] = None,
    registry=None,
) -> TrainingSet:
    """Export a point-in-time-correct training set for ``view``.

    ``columns`` is the full primary-table history ({col: (N,) array}),
    ``secondary`` the full secondary-table histories for multi-table
    views.  Label rows come from ``label_rows`` (history row indices) or
    are sampled with :func:`sample_label_rows` (``n`` rows, ``seed``).
    ``label`` names a primary column to carry along as the target.

    When a :class:`~repro.core.view.FeatureRegistry` is passed, the
    export is recorded as a deployment of service ``export:<view>`` —
    training-set lineage surfaces in the generated catalog next to the
    serving deployments.
    """
    tel = get_telemetry()
    engine = engine or OfflineEngine()
    schema = view.schema
    ts_all = np.asarray(columns[schema.ts])
    key_all = np.asarray(columns[schema.key])
    if label_rows is None:
        if n is None:
            raise ValueError("export_training_set needs label_rows= or n=")
        label_rows = sample_label_rows(ts_all, n, seed=seed)
    label_rows = np.asarray(label_rows, np.int64)

    with tel.tracer.span(
        "export", view=view.name, rows=int(label_rows.shape[0]),
        history_rows=int(ts_all.shape[0]),
    ):
        feats = engine.compute(view, columns, secondary or {})
        features = {
            f: np.asarray(v)[label_rows].astype(np.float32)
            for f, v in feats.items()
        }

    out = TrainingSet(
        view=view.name,
        version=view.version,
        rows=label_rows,
        key=key_all[label_rows],
        ts=ts_all[label_rows],
        features=features,
        label=(
            np.asarray(columns[label])[label_rows]
            if label is not None else None
        ),
    )

    m = tel.metrics
    m.counter(
        "export_rows_total", "training-set rows exported", "1",
        labels=("view",),
    ).inc(len(out), view=view.name)
    # label staleness vs the newest history the export saw — the offline
    # mirror of ingest freshness (how far behind "now" each sample is)
    newest = int(ts_all.max()) if ts_all.size else 0
    fresh = m.histogram(
        "export_freshness_seconds",
        "newest-history-ts minus label-ts per exported row", "s",
        labels=("view",),
    )
    ages, counts = np.unique(
        (newest - out.ts).astype(np.int64), return_counts=True
    )
    for age, cnt in zip(ages, counts):
        fresh.observe(float(age), n=int(cnt), view=view.name)

    if registry is not None:
        try:
            registry.get(view.name, view.version)
        except KeyError:
            registry.register(view)
        registry.deploy(
            f"export:{view.name}", view.name, view.version,
            description=(
                f"training-set export ({len(out)} rows, "
                f"{len(out.features)} features, seed={seed})"
            ),
        )
    return out


@dataclasses.dataclass
class ExportCheck:
    """Export-vs-online-replay verification result (one view)."""

    view: str
    history_rows: int
    label_rows: int
    n_features: int
    max_abs_err: float
    per_feature: Dict[str, float]
    passed: bool
    mode: str

    def summary(self) -> str:
        flag = "PASS" if self.passed else "FAIL"
        return (
            f"[{flag}] export view={self.view} labels={self.label_rows}/"
            f"{self.history_rows} features={self.n_features} "
            f"max_abs={self.max_abs_err:.3e} (mode={self.mode})"
        )


def verify_export(
    view: FeatureView,
    columns: Dict[str, np.ndarray],
    training: TrainingSet,
    *,
    num_keys: int,
    capacity: int = 256,
    num_buckets: int = 64,
    bucket_size: int = 64,
    mode: str = "preagg",
    rtol: float = 2e-4,
    atol_scale: float = 1e-3,
    secondary: Optional[Dict[str, Dict[str, np.ndarray]]] = None,
    secondary_num_keys: Optional[Dict[str, int]] = None,
    num_shards: Optional[int] = None,
) -> ExportCheck:
    """Row-for-row check: the exported batch equals a live online replay.

    Replays the full history through an online store with
    :func:`~repro.core.consistency.verify_view`'s exact protocol
    (ts-ordered rounds of unique keys; secondary rows interleaved by
    timestamp; query-then-ingest so each request's window includes
    itself), collects the online answers at ``training.rows``, and
    compares under the same scale-aware f32 tolerance.  The online store
    here retains only ``capacity`` rows per key — label rows older than
    the retention horizon still must agree, because both sides'
    *point-in-time* answers for a row depend only on rows at ``ts <=``
    its own, which the replay had ingested by then.
    """
    secondary = secondary or {}
    store = OnlineFeatureStore.create(
        view,
        num_keys=num_keys,
        num_shards=num_shards,
        capacity=capacity,
        num_buckets=num_buckets,
        bucket_size=bucket_size,
        secondary_num_keys=secondary_num_keys,
    )
    schema = view.schema
    key = np.asarray(columns[schema.key])
    ts = np.asarray(columns[schema.ts])
    n = len(key)
    want = set(np.asarray(training.rows, np.int64).tolist())

    sec_events: Dict[str, Dict] = {}
    for t in store._sec_names:
        tsch = view.database.table(t)
        tcols = {c: np.asarray(v) for c, v in secondary[t].items()}
        order = np.argsort(tcols[tsch.ts], kind="stable")
        sec_events[t] = {
            "cols": {c: v[order] for c, v in tcols.items()},
            "ts": tcols[tsch.ts][order],
            "keycol": tsch.key,
            "tscol": tsch.ts,
            "pos": 0,
        }

    def ingest_secondary_upto(tmax: int) -> None:
        for t, ev in sec_events.items():
            hi = int(np.searchsorted(ev["ts"], tmax, side="right"))
            if hi <= ev["pos"]:
                continue
            sl = slice(ev["pos"], hi)
            ev["pos"] = hi
            batch = {c: v[sl] for c, v in ev["cols"].items()}
            sort = np.lexsort((batch[ev["tscol"]], batch[ev["keycol"]]))
            store.ingest_table(t, {c: v[sort] for c, v in batch.items()})

    online = {f: np.zeros(n, np.float32) for f in view.features}
    for idx in replay_rounds(key, ts):
        ingest_secondary_upto(int(ts[idx].max()))
        batch = {c: np.asarray(columns[c])[idx] for c in columns}
        if any(int(i) in want for i in idx):
            res = store.query(batch, mode=mode)
            for f, v in res.items():
                online[f][idx] = np.asarray(v)
        sort = np.lexsort((ts[idx], key[idx]))
        store.ingest({c: batch[c][sort] for c in batch})

    rows = np.asarray(training.rows, np.int64)
    max_abs = 0.0
    per_feature: Dict[str, float] = {}
    ok = True
    for f in view.features:
        a = training.features[f].astype(np.float64)
        b = online[f][rows].astype(np.float64)
        abs_err = np.abs(a - b)
        per_feature[f] = float(abs_err.max(initial=0.0))
        max_abs = max(max_abs, per_feature[f])
        scale = float(np.percentile(np.abs(a), 99)) if a.size else 1.0
        atol_f = atol_scale * max(1.0, scale)
        if not np.allclose(a, b, rtol=rtol, atol=atol_f):
            ok = False
    return ExportCheck(
        view=view.name,
        history_rows=n,
        label_rows=int(rows.shape[0]),
        n_features=len(view.features),
        max_abs_err=max_abs,
        per_feature=per_feature,
        passed=ok,
        mode=mode if num_shards is None else f"{mode}/shards={num_shards}",
    )
