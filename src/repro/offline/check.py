"""CI gate: training-set export must equal online replay row-for-row.

``python -m repro.offline.check`` (scripts/ci.sh runs it after pytest)
exports a point-in-time training set from the canonical multi-table view
(LAST JOINs + a WINDOW UNION stream) over a synthetic history, then
replays the same history through live online stores — single-device and
sharded — and requires the exported rows to match the online answers at
every label row under the repo's f32 tolerance contract.

The online stores run with a *small* ring capacity on purpose: most
label rows are beyond the rings' retention horizon by the end of the
replay, which is exactly the regime the export path exists for —
training labels must not stop where ring capacity does.
"""

from __future__ import annotations

from repro.hostdevices import force_host_devices

force_host_devices(8)  # the sharded replay wants a multi-device platform

import sys

import numpy as np

from repro.data.synthetic import multitable_stream
from repro.offline.export import export_training_set, verify_export
from repro.scenarios import multi_table_view

NUM_ACCOUNTS = 16
NUM_MERCHANTS = 8
HIST_ROWS = 400
T_MAX = 20_000
CAPACITY = 16          # << rows/key: labels straddle the retention horizon
N_LABELS = 96
SHARD_COUNTS = (None, 4)


def main() -> int:
    rng = np.random.default_rng(7)
    view = multi_table_view()
    tables = multitable_stream(
        rng, HIST_ROWS, num_accounts=NUM_ACCOUNTS,
        num_merchants=NUM_MERCHANTS, t_max=T_MAX,
    )
    primary = tables["transactions"]
    secondary = {t: tables[t] for t in ("wires", "accounts", "merchants")}

    training = export_training_set(
        view, primary, n=N_LABELS, seed=3, secondary=secondary,
    )
    rows_per_key = HIST_ROWS / NUM_ACCOUNTS
    print(training.describe())
    print(
        f"history: {HIST_ROWS} rows over {NUM_ACCOUNTS} accounts "
        f"(~{rows_per_key:.0f}/key), online capacity {CAPACITY}/key "
        "-> label rows reach beyond the retention horizon"
    )

    ok = True
    for shards in SHARD_COUNTS:
        check = verify_export(
            view, primary, training,
            num_keys=NUM_ACCOUNTS,
            capacity=CAPACITY,
            secondary=secondary,
            secondary_num_keys={"merchants": NUM_MERCHANTS},
            num_shards=shards,
        )
        print(check.summary())
        ok = ok and check.passed
    if not ok:
        print("export-vs-replay check FAILED", file=sys.stderr)
        return 1
    print("training-set export matches online replay row-for-row: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
