"""Offline half of the feature lifecycle — backfill bridge + training-set
export (FeatInsight's offline scenario, ROADMAP item 3).

Two consumers of the same offline history (:class:`BackfillSource`):

* **Backfill** (:mod:`repro.offline.backfill`): re-derives aged-out ring
  rows and bucket pre-aggregate states from per-table history and splices
  them into a migrating plane, so hot deployments that need state beyond
  the rings' retention horizon stay bit-exact instead of refusing or
  reporting ``exact=False``.
* **Export** (:mod:`repro.offline.export`): point-in-time-correct
  training sets from the *same* :class:`~repro.core.view.FeatureView`
  definitions that serve online, verified row-for-row against an online
  replay — training/serving consistency as a generated artifact.
"""

from repro.offline.backfill import (
    BackfillAction,
    BackfillPlan,
    BackfillSource,
)
from repro.offline.export import (
    ExportCheck,
    TrainingSet,
    export_training_set,
    sample_label_rows,
    verify_export,
)

__all__ = [
    "BackfillAction",
    "BackfillPlan",
    "BackfillSource",
    "ExportCheck",
    "TrainingSet",
    "export_training_set",
    "sample_label_rows",
    "verify_export",
]
