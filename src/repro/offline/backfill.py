"""Offline backfill bridge — exact migrations beyond the retention horizon.

The online plane's rings retain only the last ``capacity`` rows per key,
so a hot deployment that needs older state (a capacity grow after rows
aged out, a placement change over a wrapped ring, a lane that cannot be
synthesized from stored f32 columns) either refuses or completes with
``report.exact = False``.  FeatInsight's answer is its offline half: the
full history lives in offline storage, and feature state is *re-derived*
from it with the same computation that ran online.

:class:`BackfillSource` is that bridge for the JAX stores.  Given
per-table raw-column history (exactly the column batches that were
ingested online, any order), it

* re-derives **ring state**: lane values via the same
  :func:`~repro.core.expr.eval_rowlevel` f32 evaluation ingest uses
  (elementwise, so bit-exact row-for-row — including hash/signature
  lanes the lane-synthesis path must refuse), laid out with the ring's
  own cursor arithmetic (row at absolute index ``a`` lands in slot
  ``a % C``) and the store's own shard routing;
* re-derives **bucket pre-aggregate state**: per-(key, bucket) algebra
  folds in the canonical ``lexsort((ts, key))`` stream order with
  unbuffered left-to-right f32 accumulation — the association
  ``bucket_ingest`` applies — over *all* history rows, not just the
  ring-retained suffix;
* **splices** the re-derived state over every structured
  :class:`~repro.core.migrate.Deficit` a migration recorded, restoring
  ``report.exact`` (hot == cold rebuild + full replay, bit-for-bit).

Safety contract: the splice runs *before* the new layout goes live
(:meth:`~repro.core.online.OnlineFeatureStore.adopt_layout`), and it
verifies the re-derived per-key row counts against the live store's
cursors — a history that does not reproduce the online stream raises
loudly and leaves the plane serving the old layout, exactly like a
refused migration.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import preagg as pg
from repro.core import storage as st
from repro.core.aggregates import (
    LANES,
    NEG_INF,
    POS_INF,
    TOPN_TAIL,
    row_bitmap,
)
from repro.core.expr import (
    collect_last_joins,
    collect_window_aggs,
    eval_rowlevel,
)
from repro.core.layout import LayoutDiff, RingPlan
from repro.core.migrate import MigrationReport, _collect_cols, _mk_ring
from repro.core.online import OnlineState
from repro.obs import get_telemetry

__all__ = ["BackfillAction", "BackfillPlan", "BackfillSource"]

_TS_MIN = np.int32(-2147483648)

_IDENT = {
    "sum": np.float32(0.0),
    "count": np.float32(0.0),
    "min": np.float32(POS_INF),
    "max": np.float32(NEG_INF),
    "sumsq": np.float32(0.0),
}


@dataclasses.dataclass(frozen=True)
class BackfillAction:
    """One state re-derivation the splice will perform (or refuse).

    Mirrors the :class:`~repro.core.migrate.Deficit` it repairs, plus the
    offline side of the ledger: how many history rows the source holds
    for the table (``rows``; per-shard breakdown for partitioned rings)
    and whether the source actually covers the re-derivation
    (``covered`` — table present, every needed raw column present, keys
    inside the plan's domain).
    """

    target: str                       # 'ring' | 'bucket'
    table: str
    ring: Optional[int] = None        # new.tables index; None = primary
    lanes: Optional[Tuple] = None
    rows: int = 0
    rows_per_shard: Tuple[int, ...] = ()
    covered: bool = True
    reason: str = ""

    def describe(self) -> str:
        what = (
            "all lanes" if self.lanes is None
            else ", ".join(repr(k) for k in self.lanes)
        )
        tag = "" if self.covered else f"  UNCOVERED: {self.reason}"
        return (
            f"{self.target} {self.table} [{what}] "
            f"<- {self.rows} history rows{tag}"
        )


@dataclasses.dataclass
class BackfillPlan:
    """What a backfill splice will do for one migration's deficits."""

    actions: List[BackfillAction] = dataclasses.field(default_factory=list)

    @property
    def covered(self) -> bool:
        return all(a.covered for a in self.actions)

    @property
    def total_rows(self) -> int:
        return sum(a.rows for a in self.actions)

    def describe(self) -> str:
        if not self.actions:
            return "backfill plan: nothing to re-derive"
        lines = [
            f"backfill plan: {len(self.actions)} action(s), "
            f"{self.total_rows} history rows, "
            f"covered={'yes' if self.covered else 'NO'}"
        ]
        for a in self.actions:
            lines.append(f"  {a.describe()}")
        return "\n".join(lines)


def _features_needing(view, table: str, lanes: Optional[Tuple]) -> List[str]:
    """Best-effort: which view features depend on the deficient state
    (for refusal messages that name the offender)."""
    names: List[str] = []
    for fname, expr in view.features.items():
        waggs = collect_window_aggs([expr])
        ljs = collect_last_joins([expr])
        if lanes:
            if any(wa.arg.key in lanes for wa in waggs.values()) or any(
                lj.arg.key in lanes for lj in ljs.values()
            ):
                names.append(fname)
            continue
        if any(table in wa.union for wa in waggs.values()) or any(
            lj.table == table for lj in ljs.values()
        ):
            names.append(fname)
    if not names and lanes is None:
        # primary-table deficits touch every windowed feature
        names = [
            f for f, e in view.features.items() if collect_window_aggs([e])
        ]
    return names


class BackfillSource:
    """Per-table raw-column history, servable into a migrating plane.

    ``tables`` maps table name -> column dict (including the schema's key
    and ts columns), holding the *complete* stream that was ingested
    online — same values, same dtypes, in ingest order (ties in
    ``(key, ts)`` keep their original relative order, matching the
    store's stable batch sorts).  Feed it to
    ``MultiScenarioService.hot_deploy(view, backfill=source)`` /
    ``ScenarioPlane.evolve`` / ``OnlineFeatureStore.adopt_layout``; the
    export side (:mod:`repro.offline.export`) reads the same object.
    """

    def __init__(self, database, tables: Dict[str, Dict[str, np.ndarray]]):
        self.database = database
        self.tables: Dict[str, Dict[str, np.ndarray]] = {}
        for name, cols in tables.items():
            sch = database.table(name)  # raises on unknown tables
            missing = [c for c in (sch.key, sch.ts) if c not in cols]
            if missing:
                raise ValueError(
                    f"backfill history for table {name!r} lacks required "
                    f"column(s) {missing} (schema key={sch.key!r}, "
                    f"ts={sch.ts!r})"
                )
            arrs = {c: np.asarray(v) for c, v in cols.items()}
            sizes = {c: a.shape[0] for c, a in arrs.items()}
            if len(set(sizes.values())) > 1:
                raise ValueError(
                    f"backfill history for table {name!r} has ragged "
                    f"columns: {sizes}"
                )
            self.tables[name] = arrs
        self._streams: Dict[str, Tuple] = {}

    # -- history access -----------------------------------------------------

    def rows(self, table: str) -> int:
        return (
            0 if table not in self.tables
            else next(iter(self.tables[table].values())).shape[0]
        )

    def stream(self, table: str):
        """Canonical history stream of ``table``:
        ``(key (N,) i64, ts (N,) i32, columns {name: (N,)})`` sorted by
        the store's canonical ``lexsort((ts, key))`` order — the order
        every exact fold below replays."""
        if table in self._streams:
            return self._streams[table]
        sch = self.database.table(table)
        cols = self.tables[table]
        key = np.asarray(cols[sch.key]).astype(np.int64)
        ts = np.asarray(cols[sch.ts]).astype(np.int32)
        order = np.lexsort((ts, key))
        out = (
            key[order],
            ts[order],
            {c: np.asarray(v)[order] for c, v in cols.items()},
        )
        self._streams[table] = out
        return out

    # -- coverage -----------------------------------------------------------

    def covers(self, table: str, expr) -> bool:
        """Can ``expr``'s lane be re-derived for ``table`` from this
        history?  (The migration's deferral hook — a lane is only
        zero-filled for the splice when this says yes.)"""
        if table not in self.tables:
            return False
        cols = self.tables[table]
        return all(c in cols for c in _collect_cols(expr))

    def _plan_coverage(
        self, plan: RingPlan, lanes: Optional[Tuple]
    ) -> Optional[str]:
        """None when every needed lane of ``plan`` is derivable from the
        history; otherwise why not."""
        if plan.table not in self.tables:
            return (
                f"backfill source holds no history for table "
                f"{plan.table!r} (has {sorted(self.tables)})"
            )
        cols = self.tables[plan.table]
        need = (
            plan.lanes if lanes is None
            else [s for s in plan.lanes if s.key in lanes]
        )
        for slot in need:
            missing = [c for c in _collect_cols(slot.expr) if c not in cols]
            if missing:
                return (
                    f"lane {slot.key!r} of table {plan.table!r} needs raw "
                    f"column(s) {missing} absent from the backfill history"
                )
        return None

    # -- planning -----------------------------------------------------------

    def plan(
        self, diff: LayoutDiff, report: MigrationReport, store
    ) -> BackfillPlan:
        """Resolve a migration's deficits against this history: one
        :class:`BackfillAction` per deficit, with coverage verdicts and
        per-(table, shard) history row counts.  Pure introspection — no
        state is touched (``splice`` executes covered plans)."""
        sharded = diff.new.num_shards is not None
        S = diff.new.num_shards or 1
        out = BackfillPlan()
        for d in report.deficits:
            ring_plan = (
                diff.new.primary if d.target == "bucket" or d.ring is None
                else diff.new.tables[d.ring]
            )
            # a ring deficit rebuilds the WHOLE ring (every lane needs its
            # raw columns); a per-lane bucket re-fold needs only its own
            need_lanes = d.lanes if d.target == "bucket" else None
            why = self._plan_coverage(ring_plan, need_lanes)
            rows = self.rows(ring_plan.table)
            per_shard: Tuple[int, ...] = ()
            if why is None and rows:
                key, _, _ = self.stream(ring_plan.table)
                if ring_plan.partitioned and sharded:
                    try:
                        s_ids, _ = store._route_ids(key, ring_plan.num_keys)
                        per_shard = tuple(
                            np.bincount(s_ids, minlength=S).tolist()
                        )
                    except ValueError as e:
                        why = str(e)
                elif key.size and (
                    key.min() < 0 or key.max() >= ring_plan.num_keys
                ):
                    why = (
                        f"history keys of table {ring_plan.table!r} fall "
                        f"outside [0, {ring_plan.num_keys}) "
                        f"(seen [{key.min()}, {key.max()}])"
                    )
                else:
                    per_shard = (rows,) * S
            out.actions.append(BackfillAction(
                target=d.target,
                table=ring_plan.table,
                ring=d.ring,
                lanes=d.lanes,
                rows=rows,
                rows_per_shard=per_shard,
                covered=why is None,
                reason=why or "",
            ))
        return out

    # -- re-derivation ------------------------------------------------------

    def _lane_values(
        self,
        plan: RingPlan,
        columns: Dict,
        lane_js: Optional[List[int]] = None,
    ) -> np.ndarray:
        """(N, max(F, 1)) f32 lane block over the history stream — the
        exact values ingest computed: elementwise
        ``eval_rowlevel(expr, raw_columns)`` over same-dtype inputs, so
        hash/signature lanes reproduce bit-for-bit.  ``lane_js`` restricts
        evaluation to those lane indices (others stay zero), so a
        per-lane bucket re-fold only needs *its* raw columns."""
        n = next(iter(columns.values())).shape[0] if columns else 0
        out = np.zeros((n, max(len(plan.lanes), 1)), np.float32)
        if not plan.lanes:
            return out
        jcols = {c: jnp.asarray(v) for c, v in columns.items()}
        js = range(len(plan.lanes)) if lane_js is None else lane_js
        for j in js:
            out[:, j] = np.asarray(
                eval_rowlevel(plan.lanes[j].expr, jcols, {}).astype(
                    jnp.float32
                )
            )
        return out

    def _routed(self, plan: RingPlan, key: np.ndarray, store, sharded: bool):
        """(shard (N,), local-row (N,)) placement of history keys under
        the store's own routing (range-checked: out-of-domain history
        keys raise, they can never be spliced silently)."""
        if plan.partitioned and sharded:
            return store._route_ids(key, plan.num_keys)
        if key.size and (key.min() < 0 or key.max() >= plan.num_keys):
            raise ValueError(
                f"history keys of table {plan.table!r} fall outside "
                f"[0, {plan.num_keys}) (seen [{key.min()}, {key.max()}])"
            )
        return np.zeros(key.shape, np.int64), key

    def _derive_ring(self, plan: RingPlan, store, sharded: bool, S: int):
        """Re-derive one ring wholesale from history: returns
        ``(ts (S,K,C), vals (S,K,C,F), cur (S,K))`` — byte-identical to a
        ring that ingested the full stream at this plan all along."""
        key, ts, cols = self.stream(plan.table)
        lanes = self._lane_values(plan, cols)
        K_t, C = plan.ring_keys, plan.capacity
        F = max(len(plan.lanes), 1)
        ts_n = np.full((S, K_t, C), _TS_MIN, np.int32)
        vals_n = np.zeros((S, K_t, C, F), np.float32)
        cur_n = np.zeros((S, K_t), np.int32)
        s_all, l_all = self._routed(plan, key, store, sharded)
        part = plan.partitioned and sharded
        for g in np.unique(key):
            idx = np.nonzero(key == g)[0]  # canonical order preserved
            c = len(idx)
            r = min(c, C)
            tail = idx[c - r:]
            slots = np.arange(c - r, c, dtype=np.int64) % C
            if part:
                s, l = int(s_all[idx[0]]), int(l_all[idx[0]])
                ts_n[s, l, slots] = ts[tail]
                vals_n[s, l, slots] = lanes[tail]
                cur_n[s, l] = c
            else:
                l = int(l_all[idx[0]])
                ts_n[:, l, slots] = ts[tail]
                vals_n[:, l, slots] = lanes[tail]
                cur_n[:, l] = c
        return ts_n, vals_n, cur_n

    def _verify_cursors(
        self, plan: RingPlan, cur_new: np.ndarray, cur_live: np.ndarray
    ) -> None:
        """The exactness tripwire: re-derived per-key row counts must
        equal the live (migrated) cursors — the store's rows-ever ledger.
        Anything else means the history is not the online stream."""
        if np.array_equal(cur_new, cur_live):
            return
        bad = int((cur_new != cur_live).sum())
        s, k = np.argwhere(cur_new != cur_live)[0]
        raise ValueError(
            f"backfill history for table {plan.table!r} does not reproduce "
            f"the online stream: per-key row counts disagree with the live "
            f"store's cursors at {bad} ring row(s) (e.g. shard {int(s)} "
            f"row {int(k)}: history has {int(cur_new[s, k])} rows, the "
            f"store ingested {int(cur_live[s, k])}); the splice needs "
            f"exactly the rows that were ingested online — rebuild the "
            f"plane or fix the history"
        )

    def _derive_bucket(
        self,
        diff: LayoutDiff,
        bagg,
        store,
        sharded: bool,
        S: int,
        full: bool,
        lane_keys: List[Tuple],
    ):
        """Re-fold bucket pre-aggregate states from the full primary
        history (``full`` rebuilds ids + every lane after a
        ``num_buckets`` wraparound; otherwise only ``lane_keys`` re-fold
        over the migrated — exact — bucket ids).

        Unbuffered ``np.*.at`` folds apply per cell in stream order, so
        the f32 association matches ``bucket_ingest`` left-to-right —
        the same argument :func:`repro.core.migrate._rebuild_bucket_lane`
        relies on, extended over the whole history instead of the ring's
        retained suffix.
        """
        dst_p = diff.new.primary
        NB = diff.new.bucket.num_buckets
        bsize = diff.new.bucket.bucket_size
        key, ts, cols = self.stream(dst_p.table)
        if full:
            lane_js = list(range(len(dst_p.lanes))) or [0]
        else:
            lane_js = [dst_p.lane_of(k) for k in lane_keys]
        # merge-order families rebuild whole-array (winner rows are
        # lane-shared), so their value gathers need every lane evaluated
        want_ext = getattr(diff.new.bucket, "extreme", False)
        want_tail = getattr(diff.new.bucket, "tail", False)
        eval_js = (
            (list(range(len(dst_p.lanes))) or [0])
            if (want_ext or want_tail)
            else lane_js
        )
        lanes = self._lane_values(
            dst_p, cols, lane_js=[j for j in eval_js if dst_p.lanes]
        )
        K = dst_p.ring_keys

        stats = np.array(np.asarray(bagg.stats), np.float32, copy=True)
        bitmap = np.array(np.asarray(bagg.bitmap), np.int32, copy=True)
        bucket = np.array(np.asarray(bagg.bucket), np.int64, copy=True)
        if not sharded:
            stats, bitmap, bucket = stats[None], bitmap[None], bucket[None]

        s_all, l_all = self._routed(dst_p, key, store, sharded)
        s_all = np.asarray(s_all, np.int64)
        l_all = np.asarray(l_all, np.int64)
        b_all = ts.astype(np.int64) // bsize
        slot_all = b_all % NB

        if full:
            # stored id per slot = max bucket id ever written (the live
            # ring's newest-bucket-wins retention)
            bucket = np.full((S, K, NB), -1, np.int64)
            np.maximum.at(bucket, (s_all, l_all, slot_all), b_all)

        # rows of each slot's *surviving* bucket (earlier buckets in the
        # same slot were reset away by the newest id)
        live = bucket[s_all, l_all, slot_all] == b_all
        si, li, bi = s_all[live], l_all[live], slot_all[live]
        for j in lane_js:
            v = lanes[live][:, j].astype(np.float32)
            acc = {
                "sum": np.zeros((S, K, NB), np.float32),
                "count": np.zeros((S, K, NB), np.float32),
                "min": np.full((S, K, NB), _IDENT["min"], np.float32),
                "max": np.full((S, K, NB), _IDENT["max"], np.float32),
                "sumsq": np.zeros((S, K, NB), np.float32),
            }
            np.add.at(acc["sum"], (si, li, bi), v)
            np.add.at(acc["count"], (si, li, bi), np.float32(1.0))
            np.minimum.at(acc["min"], (si, li, bi), v)
            np.maximum.at(acc["max"], (si, li, bi), v)
            np.add.at(acc["sumsq"], (si, li, bi), v * v)
            stats[..., j, :] = np.stack([acc[l] for l in LANES], axis=-1)
            bm = np.zeros((S, K, NB), np.int32)
            np.bitwise_or.at(
                bm, (si, li, bi),
                np.asarray(row_bitmap(jnp.asarray(v)), np.int32),
            )
            bitmap[..., j] = bm
        # merge-order families, rebuilt exactly from the full history:
        # pos is the per-(shard, local-key) cumcount in canonical stream
        # order — the same arrival-order identification _derive_ring's
        # exact replay relies on
        fam_kw: Dict[str, np.ndarray] = {}
        if want_ext or want_tail:
            F = max(len(dst_p.lanes), 1)
            n_rows = int(ts.shape[0])
            gkey = s_all * np.int64(K) + l_all
            o_g = np.argsort(gkey, kind="stable")
            go = gkey[o_g]
            startg = np.ones(n_rows, bool)
            startg[1:] = go[1:] != go[:-1]
            gid = np.cumsum(startg) - 1
            firstg = np.nonzero(startg)[0]
            pos = np.empty(n_rows, np.int64)
            pos[o_g] = np.arange(n_rows) - (
                firstg[gid] if n_rows else np.zeros(0, np.int64)
            )
            seq = np.zeros((S, K), np.int64)
            np.add.at(seq, (s_all, l_all), 1)
            fam_kw["seq"] = seq.astype(np.int32)
            comb = ts.astype(np.int64) * (2 ** 32) + pos
            si_a, li_a, bi_a = s_all[live], l_all[live], slot_all[live]
            comb_l, pos_l, ts_l = comb[live], pos[live], ts[live]
            vals_l = lanes[live].astype(np.float32)  # (M, F)
            big = np.int64(2 ** 62)
        if want_ext:
            xts = np.full((S, K, NB, 2), _TS_MIN, np.int32)
            xpos = np.zeros((S, K, NB, 2), np.int32)
            xval = np.zeros((S, K, NB, F, 2), np.float32)
            xhas = np.zeros((S, K, NB, 2), bool)
            for d, (red, lim) in enumerate(
                ((np.minimum, big), (np.maximum, -big))
            ):
                w = np.full((S, K, NB), lim, np.int64)
                red.at(w, (si_a, li_a, bi_a), comb_l)
                hit = comb_l == w[si_a, li_a, bi_a]
                sh, lh, bh = si_a[hit], li_a[hit], bi_a[hit]
                xts[sh, lh, bh, d] = ts_l[hit]
                xpos[sh, lh, bh, d] = pos_l[hit]
                xval[sh, lh, bh, :, d] = vals_l[hit]
                xhas[sh, lh, bh, d] = True
            fam_kw.update(xts=xts, xpos=xpos, xval=xval, xhas=xhas)
        if want_tail:
            T = int(TOPN_TAIL)
            tts = np.full((S, K, NB, T), _TS_MIN, np.int32)
            tpos = np.zeros((S, K, NB, T), np.int32)
            tval = np.zeros((S, K, NB, F, T), np.float32)
            tvalid = np.zeros((S, K, NB, T), bool)
            cell = (si_a * np.int64(K) + li_a) * np.int64(NB) + bi_a
            o_t = np.lexsort((-comb_l, cell))  # per cell, newest first
            co = cell[o_t]
            startc = np.ones(co.size, bool)
            startc[1:] = co[1:] != co[:-1]
            cid = np.cumsum(startc) - 1
            firstc = np.nonzero(startc)[0]
            rank = np.arange(co.size) - (
                firstc[cid] if co.size else np.zeros(0, np.int64)
            )
            keep = rank < T
            rows_k, rk = o_t[keep], rank[keep]
            sk, lk, bk = si_a[rows_k], li_a[rows_k], bi_a[rows_k]
            tts[sk, lk, bk, rk] = ts_l[rows_k]
            tpos[sk, lk, bk, rk] = pos_l[rows_k]
            tval[sk, lk, bk, :, rk] = vals_l[rows_k]
            tvalid[sk, lk, bk, rk] = True
            fam_kw.update(tts=tts, tpos=tpos, tval=tval, tvalid=tvalid)
        bucket32 = bucket.astype(np.int32)
        if not sharded:
            stats, bitmap, bucket32 = stats[0], bitmap[0], bucket32[0]
            fam_kw = {k: v[0] for k, v in fam_kw.items()}
        return pg.BucketAgg(
            stats=jnp.asarray(np.ascontiguousarray(stats)),
            bitmap=jnp.asarray(np.ascontiguousarray(bitmap)),
            bucket=jnp.asarray(np.ascontiguousarray(bucket32)),
            size=bsize,
            **{
                k: jnp.asarray(np.ascontiguousarray(v))
                for k, v in fam_kw.items()
            },
        )

    # -- the splice ---------------------------------------------------------

    def splice(
        self,
        diff: LayoutDiff,
        state: OnlineState,
        report: MigrationReport,
        store,
        view,
    ) -> OnlineState:
        """Repair every deficit of a migrated state from offline history.

        Runs against the *untouched* store (before the new layout goes
        live); raises — refusing the whole deployment atomically — when
        any deficit is uncoverable or the history fails the cursor
        tripwire.  On success every deficit moves to
        ``report.backfilled`` and ``report.exact`` is restored (unless
        the migration was hard-inexact, e.g. a key-domain shrink dropped
        rows no history can resurrect).
        """
        tel = get_telemetry()
        tracer = tel.tracer
        rows_ctr = tel.metrics.counter(
            "backfill_rows_total",
            "offline history rows folded by backfill splices", "1",
            labels=("table",),
        )
        sharded = diff.new.num_shards is not None
        S = diff.new.num_shards or 1

        bplan = self.plan(diff, report, store)
        for a in bplan.actions:
            if a.covered:
                continue
            feats = _features_needing(view, a.table, a.lanes)
            named = (
                f" (feature(s) {feats})" if feats else ""
            )
            raise ValueError(
                f"cannot backfill view {view.name!r}{named}: {a.reason}; "
                "extend the backfill source's history or rebuild the "
                "plane for this deployment"
            )

        with tracer.span(
            "backfill", actions=len(bplan.actions), rows=bplan.total_rows
        ):
            ring, bagg, sec = state.ring, state.bagg, list(state.sec)
            ring_targets = sorted(
                {d.ring for d in report.deficits if d.target == "ring"},
                key=lambda r: (-1 if r is None else r),
            )
            for rix in ring_targets:
                plan = (
                    diff.new.primary if rix is None else diff.new.tables[rix]
                )
                live = state.ring if rix is None else state.sec[rix]
                with tracer.span(
                    "backfill.ring", table=plan.table,
                    rows=self.rows(plan.table),
                ):
                    ts_n, vals_n, cur_n = self._derive_ring(
                        plan, store, sharded, S
                    )
                    cur_live = np.asarray(live.cursor)
                    if not sharded:
                        cur_live = cur_live[None]
                    self._verify_cursors(plan, cur_n, cur_live)
                    rebuilt = _mk_ring(ts_n, vals_n, cur_n, sharded)
                    if rix is None:
                        ring = rebuilt
                    else:
                        sec[rix] = rebuilt
                rows_ctr.inc(self.rows(plan.table), table=plan.table)

            bdefs = [d for d in report.deficits if d.target == "bucket"]
            if bdefs:
                full = any(d.lanes is None for d in bdefs)
                lane_keys = [k for d in bdefs if d.lanes for k in d.lanes]
                with tracer.span(
                    "backfill.bucket", table=diff.new.primary.table,
                    full=full, lanes=len(lane_keys),
                ):
                    bagg = self._derive_bucket(
                        diff, bagg, store, sharded, S, full, lane_keys
                    )
                rows_ctr.inc(
                    self.rows(diff.new.primary.table),
                    table=diff.new.primary.table,
                )

            report.backfilled.extend(d.describe() for d in report.deficits)
            report.deficits.clear()
            report.exact = not report.hard_inexact
            report.notes.append(
                f"offline backfill spliced {bplan.total_rows} history "
                f"row(s) across {len(bplan.actions)} deficit(s)"
            )
        return OnlineState(ring=ring, bagg=bagg, sec=tuple(sec))
