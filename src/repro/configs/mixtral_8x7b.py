"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attn. [arXiv:2401.04088]

8 experts < 16-way model axis -> experts replicate on the model axis and
each expert FFN is tensor-sharded on d_ff (14336/16 ok): TP-MoE.  SWA
window 4096 gives the bounded rolling KV cache that makes long_500k decode
runnable.
"""
from repro.models.config import ModelConfig

ARCH_ID = "mixtral-8x7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128,
        mlp="swiglu", rope_theta=1.0e6, sliding_window=4096,
        num_experts=8, top_k=2, capacity_factor=1.25,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab=512, num_experts=4, top_k=2, sliding_window=16,
        param_dtype="float32", compute_dtype="float32",
    )
