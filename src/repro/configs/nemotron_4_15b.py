"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.models.config import ModelConfig

ARCH_ID = "nemotron-4-15b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab=256000, head_dim=128,
        mlp="squared_relu", qk_norm=False, rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, param_dtype="float32", compute_dtype="float32",
    )
