"""The paper's own scenario config: fraud-detection feature model.

A small dense transformer consuming FeatInsight feature vectors
(window-agg features + signature embeddings) — the model the online
feature service feeds in §3.3.  Not part of the 40 assigned cells; used
by examples/fraud_detection.py and the serving benchmarks.
"""
from repro.models.config import ModelConfig

ARCH_ID = "featinsight-fraud"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab=1024, head_dim=64,
        mlp="swiglu", rope_theta=10000.0, tie_embeddings=True,
        frontend="patches", frontend_len=64,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=256, frontend_len=8,
        param_dtype="float32", compute_dtype="float32",
    )
