"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427]

38 layers = 12 scanned (rec, rec, local-attn) super-blocks + 2 tail rec
blocks.  MQA (kv=1), local window 2048; state is O(window) -> long_500k
decode is runnable.
"""
from repro.models.config import ModelConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="griffin",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab=256000, head_dim=256,
        mlp="geglu", rope_theta=10000.0, sliding_window=2048,
        rnn_width=4096, conv_width=4, attn_every=3,
        tie_embeddings=True, logit_softcap=30.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=8, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab=512, rnn_width=128, sliding_window=16,
        param_dtype="float32", compute_dtype="float32",
    )
