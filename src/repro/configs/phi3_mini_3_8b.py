"""phi3-mini-3.8b [dense]: RoPE SwiGLU GQA (kv=32 == MHA). [arXiv:2404.14219]"""
from repro.models.config import ModelConfig

ARCH_ID = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064, head_dim=96,
        mlp="swiglu", rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, param_dtype="float32", compute_dtype="float32",
    )
