"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B]

64 experts / 16-way model axis = 4 experts per device: true expert
parallelism; GSPMD inserts the dispatch all-to-alls.
"""
from repro.models.config import ModelConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=163840, head_dim=128,
        mlp="swiglu", rope_theta=50000.0,
        num_experts=64, top_k=6, capacity_factor=1.3,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=64, vocab=512, num_experts=8, top_k=2,
        param_dtype="float32", compute_dtype="float32",
    )
