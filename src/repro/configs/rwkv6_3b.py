"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay.
[arXiv:2404.05892]

Runs long_500k: decode state is O(1) in context length.
"""
from repro.models.config import ModelConfig

ARCH_ID = "rwkv6-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="rwkv",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab=65536, head_dim=64,
        mlp="relu", rope_theta=0.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
        d_ff=256, vocab=512, param_dtype="float32", compute_dtype="float32",
    )
