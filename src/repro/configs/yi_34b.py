"""yi-34b [dense]: llama-arch GQA. [arXiv:2403.04652]

56 heads are NOT divisible by the 16-way model axis: the sharding rules
drop head-axis sharding for q (divisibility guard in sharding/api.py) and
GSPMD shards the fused head*dim projections instead — see DESIGN.md.
"""
from repro.models.config import ModelConfig

ARCH_ID = "yi-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab=64000, head_dim=128,
        mlp="swiglu", rope_theta=5.0e6,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, param_dtype="float32", compute_dtype="float32",
    )
