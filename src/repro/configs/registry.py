"""Architecture + shape registry: ``--arch <id>`` resolution and the
40-cell (arch x shape) matrix with applicability rules."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke_config", "cells",
           "shape_applicable", "ShapeSpec"]

ARCHS: Dict[str, str] = {
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "yi-34b": "repro.configs.yi_34b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "phi3-vision-4.2b": "repro.configs.phi3_vision_4_2b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    # the paper's own scenario (extra, not in the 40-cell matrix)
    "featinsight-fraud": "repro.configs.featinsight_fraud",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with sub-quadratic decode state (O(1) or O(window)) run long_500k
_SUBQUADRATIC = {"rwkv6-3b", "recurrentgemma-9b", "mixtral-8x7b"}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.smoke_config()


def shape_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for one cell."""
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return False, (
            "pure full attention: 500k-context decode would need a "
            "524288-entry dense KV cache and O(S) attention per token with "
            "no windowing in the published config (see DESIGN.md)"
        )
    return True, ""


def cells(include_skipped: bool = True) -> List[Tuple[str, str, bool, str]]:
    """The full 40-cell matrix: (arch, shape, runnable, skip_reason)."""
    out = []
    for arch in ARCHS:
        if arch == "featinsight-fraud":
            continue
        for shape in SHAPES:
            ok, reason = shape_applicable(arch, shape)
            if include_skipped or ok:
                out.append((arch, shape, ok, reason))
    return out
