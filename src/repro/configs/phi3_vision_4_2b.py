"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP patch frontend STUB.
[hf:microsoft/Phi-3-vision-128k-instruct]

input_specs() supplies precomputed patch embeddings (B, P, d_model)
prepended to the token sequence; labels are masked over the patch span.
"""
from repro.models.config import ModelConfig

ARCH_ID = "phi3-vision-4.2b"

N_PATCHES = 576  # 24x24 CLIP-L/14-style grid (stub)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064, head_dim=96,
        mlp="swiglu", rope_theta=10000.0,
        tie_embeddings=False,
        frontend="patches", frontend_len=N_PATCHES,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, frontend_len=16,
        param_dtype="float32", compute_dtype="float32",
    )
