"""seamless-m4t-medium [audio]: enc-dec transformer backbone.
[arXiv:2308.11596]

Frontend is a STUB per the brief: input_specs() supplies precomputed frame
embeddings (B, S_enc, d_model); 12 encoder + 12 decoder layers.  Vocab
256206 pads to 256256 (x128 alignment; padded logits masked).
"""
from repro.models.config import ModelConfig

ARCH_ID = "seamless-m4t-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        n_layers=12, n_encoder_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=256206, head_dim=64,
        mlp="relu", norm="layernorm", rope_theta=10000.0,
        tie_embeddings=True, frontend="frames",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_encoder_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
        param_dtype="float32", compute_dtype="float32",
    )
