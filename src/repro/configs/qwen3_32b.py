"""qwen3-32b [dense]: GQA + qk_norm. [hf:Qwen/Qwen3-8B scaled per assignment]"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen3-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        d_ff=25600, vocab=151936, head_dim=128,
        mlp="swiglu", qk_norm=True, rope_theta=1.0e6,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, param_dtype="float32", compute_dtype="float32",
    )
