#!/usr/bin/env bash
# Tier-1 verification entrypoint: run the repo's test suite exactly as the
# roadmap specifies, then the benchmark suite in --smoke mode (tiny N, one
# rep) so benchmark scripts cannot silently rot.
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# the sharded serving plane (tests + bench_shard) wants a multi-device CPU
# platform; respect an explicit user-provided device count
if [[ "${XLA_FLAGS:-}" != *xla_force_host_platform_device_count* ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ ${XLA_FLAGS}}"
fi
# property-test flavour: the hypothesis sweeps in test_consistency /
# test_aggregates / test_permutation activate automatically when
# hypothesis (requirements.txt) is importable; otherwise the
# deterministic always-on sweeps carry the same contracts — surface
# which flavour this run gets so a silently skipped sweep is visible
python - <<'PY'
import importlib.util
present = importlib.util.find_spec("hypothesis") is not None
print("hypothesis:", "present — randomized property sweeps active"
      if present else "absent — deterministic fallback sweeps only")
PY
python -m pytest -x -q "$@"
# telemetry gates: (1) the metrics-snapshot schema is an interface other
# tooling parses — a full workload must emit exactly the golden catalog
# (names / types / units / labels, span taxonomy, Prometheus + JSON
# render); (2) instrumentation on the request hot path must stay within a
# small multiplicative bound of the disabled-telemetry path
python -m repro.obs.check schema
python -m repro.obs.check overhead
# migration-exactness gate: hot-deploying scenario #3 onto a warm sharded
# plane must equal a cold rebuild + full replay bit-for-bit (the live
# plane-evolution contract), and must not re-ingest carried tables; phase 2
# covers the previously-refused regime — aged-out history + a new hash
# lane — made bit-exact by an offline BackfillSource
python -c "from benchmarks.bench_deploy import migration_exactness_check; migration_exactness_check()"
# offline-bridge gate: a training set exported from the serving view
# definitions must equal an online replay row-for-row, at label times
# beyond the rings' retention horizon, single-device and sharded
python -m repro.offline.check
# benchmark smoke includes bench_deploy's hot_deploy + backfill sections
# (hot-add and backfill-splice vs rebuild+replay timing, bit-exactness
# asserted) and bench_shard's multi-scenario row (3 views on one mesh vs
# isolated stores, bit-exactness gated) so the deploy path and cross-view
# routing can't silently rot
python -m benchmarks.run --smoke
# device-routing A/B gate: bench_shard's host-vs-device section (part of
# the benchmark smoke above) hard-gates bit-exactness, one fused dispatch
# per batch, and the fused compile budget, and persists per-stage span
# timings machine-readably; re-check the artifact here so a silently
# skipped section cannot pass CI, and re-assert the headline claim —
# device routing shrinks the host route+scatter share at shards >= 4
python - <<'PY'
import json
data = json.load(open("benchmarks/BENCH_route.json"))
pts = data["points"]
want = {f"{f}_s{s}" for f in ("single", "multi") for s in (1, 4, 8)}
assert set(pts) == want, sorted(pts)
for tag in sorted(want):
    assert pts[tag]["device"]["fused_dispatches"] == pts[tag]["device"]["batches"], tag
    if tag.endswith(("_s4", "_s8")):
        assert pts[tag]["device_wins"], tag
print(f"BENCH_route.json OK: {len(pts)} A/B points, device wins at S>=4")
PY
# kernel-roofline gate: bench_fold (part of the benchmark smoke above)
# persists XLA-vs-Pallas fold_levels + fused-vs-split ingest numbers to
# BENCH_fold.json; re-check the artifact so a silently skipped section
# cannot pass CI.  Bit-exact parity is gated on EVERY backend (interpret
# mode on CPU); the speed claim — Pallas >= XLA at N >= 10^6 — only
# where the kernels lower natively (TPU)
python - <<'PY'
import json
data = json.load(open("benchmarks/BENCH_fold.json"))
assert data["fold"] and data["ingest"], "empty BENCH_fold sections"
par = data["parity"]
assert par["fold_max_abs_err"] == 0.0, par
assert par["ingest_max_abs_err"] == 0.0, par
for sec, xk, pk in (("fold", "xla", "pallas"),
                    ("ingest", "split_xla", "fused_pallas")):
    for tag, pt in data[sec].items():
        assert pt[xk]["median_s"] > 0, (sec, tag)
        if data["pallas_native"] and pt["rows"] >= 10**6:
            assert pt[pk]["median_s"] <= pt[xk]["median_s"], (
                f"{sec} {tag}: Pallas slower than XLA on TPU")
n = len(data["fold"]) + len(data["ingest"])
print(f"BENCH_fold.json OK: {n} points, parity exact, "
      f"backend={data['backend']}")
PY
# scenario-explosion smoke: 16 generated views on one 8-shard plane must
# survive 2 hot-deploy churn waves with mixed-scenario traffic under both
# routing flavours, fused-vs-host parity probes, plane==dedicated-store
# spot checks, and a seeded rotating offline==online verification subset
# (full sweep: `pytest -m stress`; failures shrink to a minimal repro).
# Ingest inside the waves rides the fused-ingest dispatcher (impl="auto":
# the one-pass Pallas kernel on TPU, its bit-identical XLA oracle here),
# so the fused path is exercised under churn on every CI run
python -m repro.stress --smoke
# compile-time budget: offline MIN/MAX at N=5k must compile in < 30 s (the
# seed's sparse-table formulation took ~150 s; keep the blowup dead)
python -c "from benchmarks.bench_window_agg import compile_budget_check; compile_budget_check(5000, 30.0)"
# docs gate: the generated feature catalog must match the live view
# definitions (regenerate-and-diff; run `python -m repro.catalog` to fix)
python -m repro.catalog --check
