#!/usr/bin/env bash
# Tier-1 verification entrypoint: run the repo's test suite exactly as the
# roadmap specifies.  Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
