"""Quickstart — the FeatInsight §3.1 end-to-end loop in ~80 lines.

  1. import data        (CSV -> typed columns)
  2. create features    (declarative DAG -> feature view + lineage)
  3. offline compute    (export a training set)
  4. online service     (ingest stream, point queries)
  5. consistency check  (offline batch == online incremental)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import io

import numpy as np

from repro.core import (
    Col, FeatureRegistry, FeatureView, OfflineEngine, OnlineFeatureStore,
    range_window, w_count, w_mean, w_sum,
)
from repro.core.consistency import verify_view
from repro.core.storage import TableSchema
from repro.data import load_csv

# -- 1. import data (the paper's "Data Import" button) -----------------------
SCHEMA = TableSchema(name="orders", key="user", ts="ts",
                     numeric=("price",), categorical=("product",))
CSV = io.StringIO(
    "user,ts,price,product\n" + "\n".join(
        f"{u},{t},{round(p, 2)},{pr}"
        for u, t, p, pr in zip(
            np.random.default_rng(0).integers(0, 4, 200),
            np.sort(np.random.default_rng(1).integers(0, 5000, 200)),
            np.random.default_rng(2).gamma(2.0, 30.0, 200),
            np.random.default_rng(3).integers(0, 10, 200),
        )
    )
)
table = load_csv(CSV, SCHEMA)
print(f"imported {len(table['user'])} rows into table {SCHEMA.name!r}")

# -- 2. create features (visual DAG -> SQL in the paper; a DSL here) ----------
price = Col("price")
w1k = range_window(1000, bucket=64)
view = FeatureView(
    name="user_spend", schema=SCHEMA,
    features={
        "spend_1k": w_sum(price, w1k),
        "orders_1k": w_count(price, w1k),
        "avg_1k": w_mean(price, w1k),
        "big_order": price > 100.0,
    },
    description="per-user trailing-1000s spend features",
)
registry = FeatureRegistry()
registry.register(view)
print("\nlineage of 'spend_1k':")
lin = view.lineage()["spend_1k"]
print(f"  view={lin['view']} v{lin['version']}  columns={lin['columns']}")
print(f"  sql: {lin['sql']}")

# -- 3. offline compute + training-set export ---------------------------------
engine = OfflineEngine()
feats = engine.compute(view, table)
print(f"\noffline features: {list(feats)} over {len(feats['spend_1k'])} rows")

# -- 4. online feature service ------------------------------------------------
store = OnlineFeatureStore(view, num_keys=4, num_buckets=64, bucket_size=64)
order = np.lexsort((table["ts"], table["user"]))
store.ingest({c: v[order] for c, v in table.items()})
req = {"user": np.arange(4, dtype=np.int32),
       "ts": np.full(4, 5001, np.int32),
       "price": np.full(4, 10.0, np.float32),
       "product": np.zeros(4, np.int32)}
online = store.query(req)
print("\nonline point-query (4 users):")
for f, v in online.items():
    print(f"  {f:10s} {np.asarray(v).round(2)}")

# -- 5. consistency verification ----------------------------------------------
report = verify_view(view, table, num_keys=4, num_buckets=64, bucket_size=64)
print(f"\nconsistency: {report.summary()}")
assert report.passed
print("\nquickstart OK")
