"""Scenario 4 — sharded online serving across a device mesh.

FeatInsight serves 100+ scenarios at millisecond latency because OpenMLDB
partitions online table state across nodes.  This example runs the
reproduction's sharded serving plane end to end on a multi-device CPU
(8 forced host devices), over the 4-table fraud database:

  1. deploy the multi-table view on a ShardedOnlineStore: primary rings +
     bucket pre-aggs partitioned by key%S over a ('shard',) mesh, the
     wires union stream partitioned the same way, profile tables
     (LAST JOIN targets) replicated per shard;
  2. front it with a ShardRouter: micro-batching with a max_wait_us
     deadline, shard-bucketed routing, one fused vmapped query per batch,
     answers scattered back in submission order;
  3. prove the scaling contract: the sharded answers are bit-identical
     to a single-device store fed the same stream;
  4. show the ops surface: per-shard row occupancy, request skew
     histogram, and the service's p50/p95/p99 batch latency.

Run:  PYTHONPATH=src python examples/sharded_serving.py
"""

from __future__ import annotations

# must precede any jax import: the mesh wants real (forced) host devices
from repro.hostdevices import force_host_devices

force_host_devices(8)

import jax
import numpy as np

from repro.core import OnlineFeatureStore
from repro.data.synthetic import MULTITABLE_DB, multitable_stream
from repro.scenarios import sharded_view as view
from repro.serve.router import ShardRouter
from repro.serve.service import BatchScheduler, FeatureService

NUM_SHARDS = 8
NUM_ACCOUNTS = 64
NUM_MERCHANTS = 16
HIST_ROWS = 2_000
T_MAX = 40_000
N_REQUESTS = 200


def preload(store, tables) -> None:
    for t, cols in tables.items():
        sch = MULTITABLE_DB.table(t)
        order = np.lexsort((cols[sch.ts], cols[sch.key]))
        sorted_cols = {c: v[order] for c, v in cols.items()}
        if t == "transactions":
            store.ingest(sorted_cols)
        else:
            store.ingest_table(t, sorted_cols)


def main() -> None:
    print(f"devices: {len(jax.devices())} (forced multi-device CPU)")
    rng = np.random.default_rng(0)
    v = view()
    tables = multitable_stream(
        rng, HIST_ROWS, num_accounts=NUM_ACCOUNTS,
        num_merchants=NUM_MERCHANTS, t_max=T_MAX,
    )

    # -- deploy: sharded service + single-device reference --------------------
    sharded = FeatureService.build(
        "fraud_sharded", v, num_keys=NUM_ACCOUNTS, sharded=True,
        num_shards=NUM_SHARDS,
        secondary_num_keys={"merchants": NUM_MERCHANTS},
    )
    single = FeatureService.build(
        "fraud_single", v, num_keys=NUM_ACCOUNTS,
        secondary_num_keys={"merchants": NUM_MERCHANTS},
    )
    assert isinstance(single.store, OnlineFeatureStore)
    store = sharded.store
    print(f"shards: {store.num_shards} on a "
          f"{store.mesh.devices.size}-device ('shard',) mesh")
    print(f"secondary placement: "
          f"{ {t: 'sharded' if s else 'replicated' for t, s in store._sec_sharded.items()} }")
    for svc in (sharded, single):
        preload(svc.store, tables)
    print(f"per-shard primary rows after preload: "
          f"{store.shard_row_counts().tolist()}")

    # -- serve: micro-batched request stream through the router ---------------
    router = ShardRouter(
        sharded,
        BatchScheduler(max_batch=32, max_wait_us=2_000),
        ingest=False,
    )
    reqs = [
        dict(
            account=int(rng.integers(0, NUM_ACCOUNTS)),
            ts=int(T_MAX + 1 + i),
            amount=float(rng.gamma(1.5, 60.0)),
            merchant=int(rng.integers(0, NUM_MERCHANTS)),
        )
        for i in range(N_REQUESTS)
    ]
    served = []
    now_us = 0
    for r in reqs:
        router.submit(r, now_us=now_us)
        now_us += 150  # ~6.7k QPS arrival process
        out = router.pump(now_us=now_us)
        if out is not None:
            served.append(out)
    tail = router.drain(now_us=now_us)
    if tail is not None:
        served.append(tail)
    answers = {
        k: np.concatenate([o[k] for o in served]) for k in served[0]
    }
    assert len(answers["utilization"]) == N_REQUESTS

    # -- verify: bit-identical to the single-device plane ----------------------
    batch = {k: np.asarray([r[k] for r in reqs]) for k in reqs[0]}
    ref = single.request(batch, ingest=False)
    for f in v.features:
        np.testing.assert_array_equal(answers[f], np.asarray(ref[f]))
    print(f"\nexactness: all {len(v.features)} features bit-identical to "
          f"the single-device store over {N_REQUESTS} requests")

    # -- observe ----------------------------------------------------------------
    print(f"request skew histogram (per shard): "
          f"{router.shard_histogram().tolist()}")
    st = sharded.stats
    print(f"latency: mean {st.mean_latency_ms:.2f} ms | "
          f"p50 {st.p50_ms:.2f} | p95 {st.p95_ms:.2f} | "
          f"p99 {st.p99_ms:.2f} ms over {st.batches} batches")
    print("\nsample answers (first 3 requests):")
    for f in v.features:
        print(f"  {f:>16}: {np.round(answers[f][:3], 3).tolist()}")


if __name__ == "__main__":
    main()
