"""Scenario 3 — multi-table fraud features (LAST JOIN + WINDOW UNION).

The paper's first challenge is feature engineering over "large-scale,
complex raw data" (the 2018 PHM dataset spans 17 tables).  This example
runs the multi-table plane end to end on a 4-table database:

  transactions (primary card stream)
    + wires       — second spend stream, WINDOW UNIONed into the account's
                    trailing outflow window
    + accounts    — slowly-changing profile, point-in-time LAST JOIN
    + merchants   — merchant registry, LAST JOIN on the tx's merchant id

  1. design the view: joined profile features, cross-stream union windows,
     and derived row-level math mixing both;
  2. offline: one fused jitted program computes every feature over all
     four tables (per-table sorts + searchsorted joins + union-by-merge);
  3. online: per-table ring stores answer the same definitions from device
     state; requests carry the join keys;
  4. verify: offline↔online consistency on the interleaved replay (both
     naive and preagg paths), then show the rendered SQL and lineage.

Run:  PYTHONPATH=src python examples/multi_table_fraud.py
"""

from __future__ import annotations

import numpy as np

from repro.core import FeatureRegistry, OfflineEngine, OnlineFeatureStore
from repro.core.consistency import verify_view
from repro.data.synthetic import MULTITABLE_DB, multitable_stream
from repro.scenarios import multi_table_view

N_ROWS = 3_000
NUM_ACCOUNTS = 64
NUM_MERCHANTS = 16
T_MAX = 40_000


def main() -> None:
    rng = np.random.default_rng(11)
    tables = multitable_stream(
        rng, N_ROWS, num_accounts=NUM_ACCOUNTS,
        num_merchants=NUM_MERCHANTS, t_max=T_MAX,
    )
    tx = tables["transactions"]
    secondary = {t: c for t, c in tables.items() if t != "transactions"}
    print(
        "tables:",
        ", ".join(f"{t}[{len(next(iter(c.values())))}]" for t, c in tables.items()),
    )

    # ---- 1. register the view ---------------------------------------------
    view = multi_table_view()
    registry = FeatureRegistry()
    registry.register(view)
    print(f"\nview {view.name!r} reads tables: {view.tables}")

    # ---- 2. offline batch computation -------------------------------------
    engine = OfflineEngine()
    feats = engine.compute(view, tx, secondary)
    print("\noffline features (first 3 rows):")
    for f in view.features:
        print(f"  {f:18s} {np.asarray(feats[f])[:3]}")

    # ---- 3+4. online stores + consistency verification --------------------
    for mode in ("naive", "preagg"):
        rep = verify_view(
            view, tx,
            num_keys=NUM_ACCOUNTS,
            secondary=secondary,
            secondary_num_keys={"merchants": NUM_MERCHANTS},
            mode=mode,
        )
        print(rep.summary())
        assert rep.passed, f"consistency failed in mode={mode}"

    # ---- lineage + SQL display --------------------------------------------
    lin = view.lineage()["limit_utilization"]
    print("\nlineage of limit_utilization:")
    print("  tables :", lin["tables"])
    print("  columns:", lin["columns"])
    print("  sql    :", lin["sql"])

    # a standalone online query with fresh request rows
    store = OnlineFeatureStore(
        view, num_keys=NUM_ACCOUNTS,
        secondary_num_keys={"merchants": NUM_MERCHANTS},
    )
    for t, cols in secondary.items():
        sch = MULTITABLE_DB.table(t)
        order = np.lexsort((cols[sch.ts], cols[sch.key]))
        store.ingest_table(t, {c: v[order] for c, v in cols.items()})
    order = np.lexsort((tx["ts"], tx["account"]))
    store.ingest({c: v[order] for c, v in tx.items()})
    req = {
        "account": np.arange(4, dtype=np.int32),
        "ts": np.full(4, T_MAX + 60, np.int32),
        "amount": np.asarray([10.0, 900.0, 50.0, 5000.0], np.float32),
        "merchant": np.arange(4, dtype=np.int32),
    }
    out = store.query(req)
    print("\nonline answers for 4 fresh requests:")
    for f in ("credit_limit", "outflow_sum_1h", "limit_utilization"):
        print(f"  {f:18s} {np.asarray(out[f])}")

    # ---- the offline half: point-in-time training-set export ---------------
    # same view definitions, full history, label rows sampled across the
    # stream (including beyond any online ring's retention horizon); the
    # registry records the export as lineage next to the serving deploys
    from repro.offline import export_training_set

    training = export_training_set(
        view, tx, n=256, seed=7, label="amount", secondary=secondary,
        registry=registry,
    )
    print(f"\n{training.describe()}")
    dep = registry.deployments(view.name)[-1]
    print(
        f"registry records: service={dep['service']!r} "
        f"({dep['description']})"
    )


if __name__ == "__main__":
    main()
