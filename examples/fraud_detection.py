"""Scenario 2 (§3.3) — online fraud detection, end to end.

The full production loop on a reduced scale:

  1. design the fraud feature view (trailing windows + device novelty),
  2. offline: export the training set, train the scoring transformer
     (featinsight-fraud smoke config) on those features,
  3. online: deploy view + model as a ScoringService, replay the unseen
     tail of the stream through it (query -> score -> ingest),
  4. report: serving latency / QPS, and recall vs an amount-threshold
     baseline — the paper's claim is that window features lift recall
     while staying inside the latency budget.

Offline/online consistency (§2) is what makes step 2 -> 3 legitimate:
the model trains on offline features and serves on online features
computed by the same definition.

Run:  PYTHONPATH=src python examples/fraud_detection.py [--steps 150]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.featinsight_fraud import smoke_config
from repro.core import FeatureRegistry, OfflineEngine, OnlineFeatureStore
from repro.data.synthetic import fraud_stream
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.scenarios import fraud_view
from repro.serve.service import FeatureService, ScoringService

N_ROWS = 4_000
NUM_CARDS = 64
SPLIT = 0.8

# the canonical fraud view (repro.scenarios / docs/CATALOG.md) includes a
# 6h window: the online stores need enough pre-agg buckets to cover it
STORE_KW = dict(num_keys=NUM_CARDS, num_buckets=512, bucket_size=64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    rng = np.random.default_rng(42)
    cols, label = fraud_stream(rng, N_ROWS, num_cards=NUM_CARDS, t_max=40_000)
    n_train = int(N_ROWS * SPLIT)
    print(f"stream: {N_ROWS} tx, fraud rate {label.mean():.3f}")

    # ---- 1+2. offline: view -> training set -> train scorer ----------------
    view = fraud_view()
    registry = FeatureRegistry()
    registry.register(view)
    engine = OfflineEngine()
    train_cols = {c: v[:n_train] for c, v in cols.items()}
    feats = engine.export_training_set(view, train_cols, label=None)
    fnames = sorted(view.features)
    X = np.stack([feats[f] for f in fnames], -1).astype(np.float32)
    y = label[:n_train]
    mu, sd = X.mean(0), X.std(0) + 1e-6

    cfg = smoke_config()
    model = build_model(cfg)
    params = model.init(0)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, decay_steps=args.steps,
                       weight_decay=0.01)
    table = jnp.asarray(rng.normal(0, 0.02, (1 << 12, cfg.d_model)), jnp.float32)

    fs_stub = FeatureService(
        "fraud_svc", view, OnlineFeatureStore(view, **STORE_KW), registry
    )
    svc = ScoringService(fs_stub, model, params, table)

    def featvec(Xb):
        Z = (Xb - mu) / sd
        pad = np.zeros((Z.shape[0], cfg.d_model - Z.shape[1]), np.float32)
        return jnp.asarray(np.concatenate([Z, pad], -1))

    def loss_fn(p, fv, emb, yb):
        prob = svc_score(p, fv, emb)
        eps = 1e-6
        return -jnp.mean(
            yb * jnp.log(prob + eps) + (1 - yb) * jnp.log(1 - prob + eps)
            + 0.0 * prob
        )

    svc_score = svc._score.__wrapped__ if hasattr(svc._score, "__wrapped__") \
        else svc._score
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    emb0 = jnp.zeros((256, cfg.d_model), jnp.float32)
    t0 = time.perf_counter()
    for step in range(args.steps):
        idx = rng.integers(0, n_train, 256)
        fv = featvec(X[idx])
        l, g = grad_fn(params, fv, emb0[: len(idx)], jnp.asarray(y[idx]))
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        params, opt, _ = adamw_update(ocfg, g, opt, jnp.dtype(cfg.param_dtype))
        if step % 50 == 0 or step == args.steps - 1:
            print(f"  train step {step:4d} loss {float(l):.4f}")
    print(f"offline training: {args.steps} steps "
          f"in {time.perf_counter() - t0:.1f}s")

    # ---- 3. online: deploy + replay the unseen tail -------------------------
    store = OnlineFeatureStore(view, **STORE_KW)
    order = np.lexsort((train_cols["ts"], train_cols["card"]))
    store.ingest({c: v[order] for c, v in train_cols.items()})
    fsvc = FeatureService("fraud_svc", view, store, registry)

    probs = np.zeros(N_ROWS - n_train, np.float32)
    B = 50  # divides the 800-row tail: one compiled batch shape
    # warm the serving executables (compile once; the paper's compilation
    # caching) before the timed replay
    warm = {c: v[:B] for c, v in train_cols.items()}
    store.query(warm)
    svc_score(params, featvec(np.zeros((B, len(fnames)), np.float32)),
              emb0[:B])
    fsvc.stats.batches = fsvc.stats.requests = 0
    fsvc.stats.total_latency_s = 0.0
    t0 = time.perf_counter()
    for i in range(n_train, N_ROWS, B):
        j = min(i + B, N_ROWS)
        rows = {c: v[i:j] for c, v in cols.items()}
        out = fsvc.request(rows, ingest=True)  # query then ingest: online loop
        Xb = np.stack([np.asarray(out[f]) for f in fnames], -1)
        fv = featvec(Xb)
        pr = svc_score(params, fv, emb0[: j - i])
        probs[i - n_train:j - n_train] = np.asarray(pr)
    dt = time.perf_counter() - t0
    n_served = N_ROWS - n_train
    print(f"online serving: {n_served} tx in {dt:.2f}s "
          f"({n_served / dt:.0f} QPS, {fsvc.stats.mean_latency_ms:.2f} ms/batch)")

    # ---- 4. recall vs baseline ----------------------------------------------
    y_test = label[n_train:]
    k = max(1, int(y_test.sum()))

    def recall_at_k(score):
        top = np.argsort(-score)[:k]
        return y_test[top].sum() / max(1, y_test.sum())

    r_model = recall_at_k(probs)
    r_base = recall_at_k(cols["amount"][n_train:])
    print(f"recall@{k}: featinsight-features model {r_model:.2f} "
          f"vs amount-threshold baseline {r_base:.2f}")
    print("fraud_detection OK")


if __name__ == "__main__":
    main()
