"""End-to-end LM training driver (reduced scale for CPU).

Exercises the full production train stack on one host:

  data pipeline -> build_train_step (microbatched grad accumulation)
  -> AdamW (fp32 master, cosine LR) -> manifest checkpoints
  -> simulated failure -> restore -> resume (fault tolerance).

The production-scale path (assigned 15-34B architectures on the 256/512
chip meshes) is `python -m repro.launch.train --arch <id> --dry-run`;
this example runs a ~6M-param config for real on CPU.  The paper's own
end-to-end driver kind is *serving* (see examples/fraud_detection.py);
this trainer shows the substrate is complete.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 40]
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.ckpt.manifest import CheckpointManager
from repro.data.synthetic import lm_stream
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import TrainSettings, build_train_step


def small_config() -> ModelConfig:
    return ModelConfig(
        name="lm-small", family="dense",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=768, vocab=2048, mlp="swiglu", tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    cfg = small_config()
    model = build_model(cfg)
    n_params = sum(
        int(np.prod(p.shape)) for p in jax.tree.leaves(model.init(0))
    )
    print(f"model: {cfg.name} {n_params/1e6:.1f}M params")

    settings = TrainSettings(
        num_microbatches=2, grad_dtype="float32",
        opt=AdamWConfig(lr_peak=1e-3, warmup_steps=20, decay_steps=args.steps),
    )
    step_fn = jax.jit(build_train_step(model, cfg, settings),
                      donate_argnums=(0, 1))

    params = model.init(0)
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    stream = lm_stream(rng, args.batch, args.seq, cfg.vocab)

    with tempfile.TemporaryDirectory() as ckdir:
        mgr = CheckpointManager(ckdir, keep=2)
        losses = []
        t0 = time.perf_counter()
        crash_at = args.steps // 2
        for step in range(crash_at):
            batch = next(stream)
            params, opt, metrics = step_fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
            if step % args.ckpt_every == 0 or step == crash_at - 1:
                mgr.save(step, {"params": params, "opt": opt}, blocking=False)
            if step % 10 == 0:
                print(f"  step {step:4d} loss {losses[-1]:.4f}")
        mgr.wait()

        # ---- simulated host failure: drop all live state ----------------
        print(f"-- simulated failure at step {crash_at}; "
              f"restoring from checkpoint --")
        del params, opt
        latest = mgr.latest_step()
        assert latest is not None
        tpl = jax.eval_shape(
            lambda: {"params": model.init(0), "opt": adamw_init(model.init(0))}
        )
        restored = mgr.restore(latest, like=tpl)
        params, opt = restored["params"], restored["opt"]
        print(f"   restored step {latest}")

        for step in range(latest + 1, args.steps):
            batch = next(stream)
            params, opt, metrics = step_fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
            if step % 10 == 0:
                print(f"  step {step:4d} loss {losses[-1]:.4f}")

        dt = time.perf_counter() - t0
        tok_s = args.steps * args.batch * args.seq / dt
        print(f"{args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s incl. "
              f"restore)")
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"loss: {first:.3f} -> {last:.3f}")
        assert last < first, "loss must decrease"
        print("train_lm OK")


if __name__ == "__main__":
    main()
