"""Scenario 1 (§3.2) — efficient feature deployment for product reco.

Vipshop-style workload: minute-level order events, features must go from
design to production fast.  The demo walks the paper's four optimizations:

  1. declarative feature design (the DSL standing in for drag-and-drop),
  2. unified executors + mechanized offline/online consistency check,
  3. compact time-series storage (ring + pre-agg ingest of the backfill),
  4. one-click deploy (define -> compile -> verify -> serve, packaged).

It then exercises version evolution: v2 adds features without redefining
v1 (the paper's cached-version reuse), and the BatchScheduler coalesces
single-row requests into fixed shape buckets (compilation caching).

Run:  PYTHONPATH=src python examples/recommendation.py
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    Col, FeatureRegistry, OfflineEngine, OnlineFeatureStore,
    range_window, rows_window, w_count, w_sum,
)
from repro.core.consistency import verify_view
from repro.data.synthetic import reco_stream
from repro.scenarios import reco_view
from repro.serve.service import BatchScheduler, FeatureService

N_ROWS = 6_000
NUM_USERS = 128


def main() -> None:
    rng = np.random.default_rng(7)
    cols = reco_stream(rng, N_ROWS, num_users=NUM_USERS)
    spend = Col("price") * Col("qty")

    # ---- one-click deploy, timed step by step ------------------------------
    t_all = time.perf_counter()
    registry = FeatureRegistry()
    engine = OfflineEngine()

    t0 = time.perf_counter()
    v1 = reco_view()  # the canonical scenario view (docs/CATALOG.md)
    registry.register(v1)
    t_design = time.perf_counter() - t0

    t0 = time.perf_counter()
    engine.compile(v1)
    engine.compute(v1, cols)
    t_compile = time.perf_counter() - t0

    t0 = time.perf_counter()
    rep = verify_view(v1, {c: np.asarray(v) for c, v in cols.items()},
                      num_keys=NUM_USERS, num_buckets=64, bucket_size=64,
                      engine=engine)
    assert rep.passed, rep.summary()
    t_verify = time.perf_counter() - t0

    t0 = time.perf_counter()
    store = OnlineFeatureStore(v1, num_keys=NUM_USERS, num_buckets=64,
                               bucket_size=64)
    order = np.lexsort((cols["ts"], cols["user"]))
    store.ingest({c: v[order] for c, v in cols.items()})
    svc = FeatureService("reco_svc", v1, store, registry)
    t_deploy = time.perf_counter() - t0

    total = time.perf_counter() - t_all
    print("one-click deployment pipeline (paper: < 1 hour, 5 person-days"
          " -> here: seconds):")
    print(f"  design    {t_design * 1e3:8.1f} ms")
    print(f"  compile   {t_compile * 1e3:8.1f} ms   (DAG -> XLA executable)")
    print(f"  verify    {t_verify * 1e3:8.1f} ms   ({rep.summary()})")
    print(f"  deploy    {t_deploy * 1e3:8.1f} ms   (backfill {N_ROWS} rows)")
    print(f"  TOTAL     {total:8.2f} s")

    # ---- request path via the batch scheduler ------------------------------
    sched = BatchScheduler(buckets=(1, 4, 16, 64))
    for i in range(23):  # 23 pending single-row requests
        sched.submit({
            "user": np.int32(rng.integers(0, NUM_USERS)),
            "ts": np.int32(90_000 + i),
            "price": np.float32(rng.gamma(2.0, 25.0)),
            "qty": np.float32(1 + i % 3),
            "product": np.int32(rng.integers(0, 512)),
            "category": np.int32(rng.integers(0, 24)),
        })
    served = 0
    while (batch := sched.next_batch()) is not None:
        valid = batch.pop("__valid__")
        out = svc.request(batch, ingest=False)  # padded fixed-shape query
        vrows = {c: v[valid] for c, v in batch.items()}
        order_v = np.lexsort((vrows["ts"], vrows["user"]))
        store.ingest({c: v[order_v] for c, v in vrows.items()})
        served += int(valid.sum())
    print(f"\nbatch scheduler served {served} queued requests "
          f"(padded to shape buckets; {svc.stats.batches} executions, "
          f"mean {svc.stats.mean_latency_ms:.2f} ms/batch)")

    # ---- v2: incremental evolution (cached-version reuse) -------------------
    t0 = time.perf_counter()
    v2 = v1.evolve(
        {"spend_24h": w_sum(spend, range_window(86_400, bucket=2048)),
         "cat_cnt_50": w_count(Col("category"), rows_window(50))},
        description="v2: + daily spend, category frequency",
    )
    registry.register(v2)
    engine.compile(v2)
    engine.compute(v2, cols)
    store2 = OnlineFeatureStore(v2, num_keys=NUM_USERS, num_buckets=128,
                                bucket_size=2048)
    store2.ingest({c: v[order] for c, v in cols.items()})
    FeatureService("reco_svc", v2, store2, registry)
    t_v2 = time.perf_counter() - t0
    print(f"\nv2 evolve+redeploy: {t_v2:.2f} s "
          f"(versions of 'user_activity': {registry.versions('user_activity')})")
    svc_info = registry.service("reco_svc")
    print(f"registry: service 'reco_svc' now at "
          f"v{svc_info['version']} of view {svc_info['view']!r}")
    print("recommendation OK")


if __name__ == "__main__":
    main()
