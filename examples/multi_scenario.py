"""Scenario 5 — multi-scenario serving: N feature views, one store, one mesh.

FeatInsight's consolidation story (100+ scenarios on one platform) in
miniature: three fraud-adjacent scenarios — account risk, spending
profile, merchant watchlist — deployed together on ONE ScenarioPlane:

  1. the views are fused into one shared store on a single ('shard',)
     mesh: lane plan = union of every view's window arguments (CSE'd, so
     the 1h outflow sum shared by two views is ONE lane), secondary
     tables = union of every view's LAST JOIN / WINDOW UNION references;
  2. shared tables are ingested once: the wires union stream and the
     accounts/merchants dimension tables serve all three scenarios from
     one ring store per (table, shard), not one per view;
  3. each view queries through its own compiled program — only its lanes
     are gathered and folded — behind one scenario-tagged ShardRouter;
  4. the third scenario is NOT part of the initial deployment: it is
     hot-deployed onto the already-warm plane (`svc.hot_deploy(view)`) —
     a StoreLayout diff + state migration, no rebuild, no re-ingest —
     and the router picks it up live;
  5. the answers (including the hot-deployed scenario's) are proven
     bit-identical to three dedicated single-view stores fed the same
     stream, and the ops surface shows per-scenario latency stats plus
     the (scenario, shard) occupancy histogram.

Run:  PYTHONPATH=src python examples/multi_scenario.py
"""

from __future__ import annotations

# must precede any jax import: the mesh wants real (forced) host devices
from repro.hostdevices import force_host_devices

force_host_devices(8)

import jax
import numpy as np

from repro.core import OnlineFeatureStore
from repro.data.synthetic import MULTITABLE_DB, multitable_stream
from repro.scenarios import multi_scenario_views
from repro.serve.router import ShardRouter
from repro.serve.service import BatchScheduler, FeatureService

NUM_SHARDS = 8
NUM_ACCOUNTS = 64
NUM_MERCHANTS = 16
HIST_ROWS = 2_000
T_MAX = 40_000
N_REQUESTS = 180

# capacity is small on purpose: ~31 rows/key age down to the newest 16,
# so the offline-bridge section below genuinely needs aged-out history
STORE_KW = dict(
    num_keys=NUM_ACCOUNTS, capacity=16, num_buckets=512, bucket_size=64,
    secondary_num_keys={"merchants": NUM_MERCHANTS},
)


def preload(store, tables) -> None:
    for t in store._sec_names:
        sch = MULTITABLE_DB.table(t)
        cols = tables[t]
        order = np.lexsort((cols[sch.ts], cols[sch.key]))
        store.ingest_table(t, {c: v[order] for c, v in cols.items()})
    tx = tables["transactions"]
    order = np.lexsort((tx["ts"], tx["account"]))
    store.ingest({c: v[order] for c, v in tx.items()})


def main() -> None:
    print(f"devices: {len(jax.devices())} (forced multi-device CPU)")
    rng = np.random.default_rng(0)
    views = multi_scenario_views()
    tables = multitable_stream(
        rng, HIST_ROWS, num_accounts=NUM_ACCOUNTS,
        num_merchants=NUM_MERCHANTS, t_max=T_MAX,
    )

    # -- 1+2: one service, two scenarios at launch, shared ingest ------------
    svc = FeatureService.build_multi(
        "consolidated", views[:2], sharded=True, num_shards=NUM_SHARDS,
        **STORE_KW,
    )
    preload(svc.plane.store, tables)
    counts = svc.plane.ingest_row_counts()
    print(f"launch scenarios: {svc.scenarios}")
    print(f"plane tables (stored once each): {svc.plane.tables}")
    print(f"stored rows per table: {counts}")

    # -- hot deploy scenario #3 on the WARM plane -----------------------------
    # a StoreLayout diff + state migration: carried rings move over
    # verbatim, nothing is re-ingested, only the new view's program
    # compiles — and the result is bit-identical to a cold rebuild + replay
    report = svc.hot_deploy(views[2])
    print(f"hot-deployed {views[2].name!r} onto the live plane:")
    print("  " + report.describe().replace("\n", "\n  "))
    assert svc.plane.ingest_row_counts() == counts, "hot deploy re-ingested!"
    print(f"scenarios now: {svc.scenarios}")

    # the dedicated-store world it replaces (for the equality proof)
    singles = {
        v.name: OnlineFeatureStore(v, **STORE_KW) for v in views
    }
    for s in singles.values():
        preload(s, tables)

    # -- 3: scenario-tagged routing through one router -----------------------
    router = ShardRouter(
        svc,
        BatchScheduler(buckets=(1, 4, 16, 64), max_batch=64,
                       max_wait_us=2_000),
        ingest=False,
    )
    names = [v.name for v in views]
    reqs, tags = [], []
    for i in range(N_REQUESTS):
        reqs.append(dict(
            account=int(rng.integers(0, NUM_ACCOUNTS)),
            ts=T_MAX + 1 + i,
            amount=float(rng.gamma(1.5, 60.0)),
            merchant=int(rng.integers(0, NUM_MERCHANTS)),
        ))
        tags.append(names[i % len(names)])
        router.submit(reqs[-1], scenario=tags[-1], now_us=i * 100)
    out = router.drain(now_us=N_REQUESTS * 100)

    # -- 5: the proof + the ops surface ---------------------------------------
    for v in views:
        idx = [i for i, t in enumerate(tags) if t == v.name]
        batch = {
            c: np.asarray([reqs[i][c] for i in idx])
            for c in ("account", "ts", "amount", "merchant")
        }
        ref = singles[v.name].query(batch)
        for f in v.features:
            np.testing.assert_array_equal(
                np.asarray(ref[f]), out[v.name][f]
            )
        st = svc.scenario_stats[v.name]
        print(
            f"  {v.name:15s} {st.requests:4d} req  "
            f"p50={st.p50_ms:6.2f}ms  p95={st.p95_ms:6.2f}ms  "
            f"features={list(v.features)}"
        )
    print("bit-identical to dedicated per-scenario stores: OK")
    print("(scenario, shard) occupancy:")
    for s, hist in router.scenario_shard_histogram().items():
        print(f"  {s:15s} {hist.tolist()}")
    print(f"aggregate: {svc.stats.requests} requests, "
          f"p50={svc.stats.p50_ms:.2f}ms p99={svc.stats.p99_ms:.2f}ms "
          f"(per-request p50={svc.stats.request_p50_ms:.2f}ms "
          f"p99={svc.stats.request_p99_ms:.2f}ms)")

    # -- the offline bridge: hot deploy beyond the retention horizon ----------
    # merchant_mix wants a 6h window of a *hash* lane: the rings retain
    # only the newest 16 rows/key (~5.5h of a ~31-row/key stream) and a
    # Signature lane can never be synthesized from stored f32 columns —
    # without offline history this deployment must refuse; with a
    # BackfillSource it re-derives the aged-out state and goes live
    # bit-exactly (capacity grows 16 -> 64 so the window fits)
    from repro.core import Col, FeatureView, Signature, range_window, w_count, w_sum
    from repro.offline import BackfillSource

    w6h = range_window(21_600, bucket=64)
    sig_view = FeatureView(
        name="merchant_mix",
        features={
            "sig_cnt_6h": w_count(Signature((Col("merchant"),), bits=8), w6h),
            "sig_sum_6h": w_sum(Signature((Col("merchant"),), bits=8), w6h),
        },
        database=MULTITABLE_DB,
        description="merchant-mix signature counts (offline-backfilled)",
    )
    print(f"\nhot-deploying {sig_view.name!r} (6h hash-lane window vs "
          "16-row rings):")
    try:
        svc.hot_deploy(sig_view, capacity=64)
    except ValueError as e:
        print(f"  without offline history: REFUSED — {str(e)[:110]}...")
    report = svc.hot_deploy(
        sig_view,
        backfill=BackfillSource(MULTITABLE_DB, tables),
        capacity=64,
    )
    assert report.exact, report.notes
    print("  with BackfillSource: " + report.describe().splitlines()[0])
    for b in report.backfilled:
        print(f"    backfilled: {b}")

    # -- the telemetry plane: freshness, compile time, migration spans -------
    from repro.obs import get_telemetry

    tel = get_telemetry()
    snap = tel.snapshot()
    print("\ntelemetry (one plane, every layer reports in):")
    fresh = tel.metrics.metrics().get("ingest_freshness_seconds")
    if fresh is not None:
        for s in fresh.snapshot()["series"]:
            print(
                f"  freshness {s['labels']['table']:15s} "
                f"p50={s['p50'] * 1e3:8.2f}ms  p95={s['p95'] * 1e3:8.2f}ms  "
                f"({s['count']:.0f} rows ingest-to-queryable)"
            )
    comp = tel.metrics.metrics().get("query_compile_seconds")
    if comp is not None:
        for s in comp.snapshot()["series"]:
            print(
                f"  compile   {s['labels']['program']:15s} "
                f"mode={s['labels']['mode']}: {s['count']:.0f} trace(s), "
                f"{s['sum'] * 1e3:.1f}ms total"
            )
    for root in tel.tracer.roots():
        if root.name == "hot_deploy":
            print("  hot-deploy span tree (⏚ = device-fenced):")
            print("    " + root.tree().replace("\n", "\n    "))
    assert any(r.name == "hot_deploy" for r in tel.tracer.roots())
    backfill_spans = [
        s for r in tel.tracer.roots() for s in r.find("backfill")
    ]
    assert backfill_spans, "the offline-bridge deploy traced no backfill"
    bf_rows = tel.metrics.metrics().get("backfill_rows_total")
    if bf_rows is not None:
        for s in bf_rows.snapshot()["series"]:
            print(
                f"  backfill  {s['labels']['table']:15s} "
                f"{s['value']:.0f} history rows re-derived offline"
            )
    print(f"  snapshot: {len(snap['metrics'])} metrics — render with "
          "`python -m repro.obs.report`")


if __name__ == "__main__":
    main()
